//! Meta-partitioning (paper §5, Algorithm 2): partition a HetG by its
//! metagraph using the HGNN computation-dependency metatree.
//!
//! Steps — (1) build the metatree by k-depth BFS from the target type
//! (or from user metapaths); (2) split it into sub-metatrees, one per
//! root child, each weighted by the node/edge counts of its types and
//! relations; (3) LPT-assign sub-metatrees to `p` partitions;
//! (4) deduplicate relations within each partition. Boundary nodes are
//! confined to the target nodes by construction, which is what gives RAF
//! its constant communication complexity (Props. 2–3).

use std::time::Instant;

use crate::hetgraph::{HetGraph, MetaTree, RelId};

use super::MetaPartition;

/// Run meta-partitioning. `depth` is the number of HGNN layers (= BFS
/// depth, Algorithm 2 line 4); `metapaths` optionally overrides BFS
/// (line 2). If there are more partitions than sub-metatrees the extra
/// partitions replicate sub-metatrees (paper §5 Discussions: replicas
/// split target nodes data-parallel); we model that by assigning
/// round-robin copies.
pub fn meta_partition(
    g: &HetGraph,
    num_parts: usize,
    depth: usize,
    metapaths: Option<&[Vec<RelId>]>,
) -> (MetaPartition, MetaTree) {
    let start = Instant::now();
    let schema = &g.schema;

    // Step 1: metatree (BFS over the weighted metagraph, or metapaths).
    let tree = match metapaths {
        Some(paths) => MetaTree::from_metapaths(schema, paths),
        None => MetaTree::build(schema, depth),
    };

    // Step 2: sub-metatrees, one per root child; weight = Σ node counts of
    // vertex types + Σ edge counts of link relations (Algorithm 2 l.8).
    let subs = tree.sub_metatrees();
    let sub_weights: Vec<u64> = subs
        .iter()
        .map(|edges| {
            let mut w: u64 = schema.node_types[schema.target].count as u64; // root vertex
            for &ei in edges {
                let e = &tree.edges[ei];
                w += schema.node_types[tree.vertices[e.child].ty].count as u64;
                w += g.rels[e.rel].num_edges() as u64;
            }
            w
        })
        .collect();

    // Step 3: LPT (longest-processing-time-first) number partitioning.
    let mut order: Vec<usize> = (0..subs.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(sub_weights[i]));
    let mut sums = vec![0u64; num_parts];
    let mut assignment = vec![0usize; subs.len()];
    for &si in &order {
        let (best, _) = sums
            .iter()
            .enumerate()
            .min_by_key(|(_, &s)| s)
            .expect("num_parts > 0");
        assignment[si] = best;
        sums[best] += sub_weights[si];
    }

    // Step 4: deduplicate relations within each partition.
    let mut rels_per_part: Vec<Vec<RelId>> = vec![Vec::new(); num_parts];
    for (si, sub) in subs.iter().enumerate() {
        let part = assignment[si];
        for &ei in sub {
            let r = tree.edges[ei].rel;
            if !rels_per_part[part].contains(&r) {
                rels_per_part[part].push(r);
            }
        }
    }
    for rels in &mut rels_per_part {
        rels.sort();
    }

    // If some partitions ended up empty (more machines than sub-metatrees),
    // replicate the heaviest sub-metatrees into them (paper Discussions).
    for part in 0..num_parts {
        if rels_per_part[part].is_empty() && !subs.is_empty() {
            let heaviest = order[part % subs.len()];
            let mut rels: Vec<RelId> = subs[heaviest]
                .iter()
                .map(|&ei| tree.edges[ei].rel)
                .collect();
            rels.sort();
            rels.dedup();
            rels_per_part[part] = rels;
        }
    }

    // Weight ownership for relations appearing in multiple partitions.
    let mut rel_owner = vec![usize::MAX; schema.relations.len()];
    for (part, rels) in rels_per_part.iter().enumerate() {
        for &r in rels {
            if rel_owner[r] == usize::MAX {
                rel_owner[r] = part;
            }
        }
    }

    // Peak memory: metatree + sub-metatree bookkeeping only — the
    // algorithm never touches per-node data (its O(|A|log|A| + |R|)
    // advantage over METIS in Table 2).
    let peak_mem_bytes = (tree.vertices.len() * 24
        + tree.edges.len() * 24
        + subs.iter().map(|s| s.len() * 8).sum::<usize>()
        + rels_per_part.iter().map(|r| r.len() * 8).sum::<usize>())
        as u64;

    (
        MetaPartition {
            num_parts,
            rels_per_part,
            rel_owner,
            assignment,
            sub_weights,
            elapsed_s: start.elapsed().as_secs_f64(),
            peak_mem_bytes,
        },
        tree,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};
    use crate::util::proptest;

    fn mag() -> HetGraph {
        generate(Preset::Mag, 1e-4, &GenParams::default())
    }

    #[test]
    fn covers_all_relations() {
        let g = mag();
        let (mp, _) = meta_partition(&g, 2, 2, None);
        let mut covered: Vec<RelId> = mp.rels_per_part.iter().flatten().copied().collect();
        covered.sort();
        covered.dedup();
        // Every relation reachable in the 2-depth metatree is covered.
        let tree = MetaTree::build(&g.schema, 2);
        let mut reachable: Vec<RelId> = tree.edges.iter().map(|e| e.rel).collect();
        reachable.sort();
        reachable.dedup();
        assert_eq!(covered, reachable);
    }

    #[test]
    fn dedup_no_duplicate_relations_within_part() {
        let g = mag();
        let (mp, _) = meta_partition(&g, 2, 2, None);
        for rels in &mp.rels_per_part {
            let mut sorted = rels.clone();
            sorted.dedup();
            assert_eq!(&sorted, rels);
        }
    }

    #[test]
    fn lpt_balances_weights() {
        let g = mag();
        let (mp, _) = meta_partition(&g, 2, 2, None);
        let loads: Vec<f64> = (0..2).map(|p| mp.part_load(&g, p) as f64).collect();
        let imb = crate::util::stats::imbalance(&loads);
        // LPT guarantees ≤ 4/3 OPT for number partitioning; with the mag
        // schema's three sub-metatrees the loads stay within 2×.
        assert!(imb < 2.0, "imbalance {imb} loads {loads:?}");
    }

    #[test]
    fn more_parts_than_subtrees_replicates() {
        let g = mag();
        let (mp, _) = meta_partition(&g, 5, 2, None);
        for p in 0..5 {
            assert!(!mp.rels_per_part[p].is_empty(), "partition {p} empty");
        }
    }

    #[test]
    fn owner_is_unique_and_valid() {
        let g = mag();
        let (mp, tree) = meta_partition(&g, 3, 2, None);
        let used: std::collections::HashSet<RelId> =
            tree.edges.iter().map(|e| e.rel).collect();
        for r in used {
            let owner = mp.rel_owner[r];
            assert!(owner < 3);
            assert!(mp.rels_per_part[owner].contains(&r));
        }
    }

    #[test]
    fn metapath_partitioning_works() {
        let g = mag();
        // Two metapaths: paper<-writes-author and paper<-cites-paper.
        let (mp, tree) = meta_partition(&g, 2, 2, Some(&[vec![0], vec![1]][..]));
        assert_eq!(tree.children_of(0).len(), 2);
        assert_eq!(mp.rels_per_part.iter().flatten().count(), 2);
    }

    #[test]
    fn prop_every_subtree_assigned_and_loads_bounded() {
        proptest::run("meta_partition_invariants", |rng, _case| {
            let scale = 3e-5 + rng.f64() * 2e-4;
            let parts = 2 + rng.below(4);
            let preset = [Preset::Mag, Preset::Donor, Preset::Mag240m][rng.below(3)];
            let g = generate(preset, scale, &GenParams { seed: rng.next_u64(), ..Default::default() });
            let (mp, tree) = meta_partition(&g, parts, 2, None);
            crate::prop_assert!(
                mp.assignment.len() == tree.sub_metatrees().len(),
                "assignment len mismatch"
            );
            crate::prop_assert!(
                mp.assignment.iter().all(|&p| p < parts),
                "invalid partition id"
            );
            // LPT bound: max load ≤ (4/3 + 1/p) × ideal when weights are
            // the sub-metatree weights themselves.
            let sums = {
                let mut s = vec![0u64; parts];
                for (si, &p) in mp.assignment.iter().enumerate() {
                    s[p] += mp.sub_weights[si];
                }
                s
            };
            let total: u64 = mp.sub_weights.iter().sum();
            let maxw = *mp.sub_weights.iter().max().unwrap_or(&0);
            let bound = (total as f64 / parts as f64 * (4.0 / 3.0)).max(maxw as f64) + 1.0;
            crate::prop_assert!(
                *sums.iter().max().unwrap() as f64 <= bound,
                "LPT bound violated: {sums:?} bound {bound}"
            );
            Ok(())
        });
    }
}
