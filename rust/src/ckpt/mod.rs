//! Epoch-boundary checkpoints: the durable half of fault tolerance.
//!
//! A checkpoint snapshots **every** piece of resumable training state —
//! the leader's [`ParamStore`] (weights *and* dense-Adam moments), the
//! learnable feature tables (weights *and* sparse-Adam moments), the
//! shared sparse-Adam timestep, the next epoch index, and a hash of the
//! trajectory-relevant config — so a killed run restored from it
//! reproduces the fault-free loss trajectory **bit-for-bit**. Everything
//! else (graph, metatree, lazy features, batch order, per-batch RNG) is
//! seed-derived from the config and re-built identically on restore,
//! which is why nothing more needs to be on disk.
//!
//! Format: a 6-byte header — [`CKPT_MAGIC`] + little-endian
//! [`CODEC_VERSION`] — followed by one [`WireCodec`] frame. The codec's
//! robustness contract applies end to end: a truncated, bit-flipped, or
//! wrong-version file decodes to an `anyhow` error naming the file,
//! never a panic. Writes are atomic (temp file + rename) so a crash
//! mid-checkpoint leaves the previous checkpoint intact; one
//! `heta.ckpt` per `--checkpoint-dir` always holds the newest epoch
//! boundary.
//!
//! Restore is epoch-granular by design: every rank re-derives its
//! seeded state for the checkpointed epoch and replays it from batch 0.
//! See `docs/FAULT_TOLERANCE.md` for the recovery protocol built on
//! top of this module.
//!
//! [`ParamStore`]: crate::runtime::ParamStore

use anyhow::{bail, ensure, Context, Result};

use crate::config::{Config, FaultSpec, TransportKind};
use crate::coordinator::Session;
use crate::kvstore::LearnableState;
use crate::net::codec::{
    decode_message, encode_message, ByteReader, ByteWriter, WireCodec, CODEC_VERSION,
};
use crate::runtime::{ParamEntry, ParamStoreState};

/// Checkpoint file magic ("Heta ChecKPoint").
pub const CKPT_MAGIC: [u8; 4] = *b"HCKP";

/// Full resumable state at one epoch boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// The next epoch to run: a checkpoint written after epoch `e`
    /// completes carries `epoch = e + 1`.
    pub epoch: usize,
    /// Shared sparse-Adam timestep for the learnable feature tables.
    pub adam_t: i32,
    /// FNV-1a hash of the trajectory-relevant config ([`config_hash`]);
    /// restoring under a config with a different hash is an error, not
    /// a silently diverging run.
    pub config_hash: u64,
    /// The leader's full parameter store (weights + Adam moments).
    pub params: ParamStoreState,
    /// Every learnable feature table (weights + sparse-Adam moments).
    pub learnable: Vec<LearnableState>,
}

impl WireCodec for ParamEntry {
    fn encode(&self, w: &mut ByteWriter) {
        w.str(&self.name);
        w.u32(self.shape.len() as u32);
        for &d in &self.shape {
            w.usize(d);
        }
        w.f32s(&self.weight);
        w.f32s(&self.m);
        w.f32s(&self.v);
        w.u32(self.t as u32);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<ParamEntry> {
        let name = r.str()?;
        let n = r.seq_len(8)?;
        let mut shape = Vec::with_capacity(n);
        for _ in 0..n {
            shape.push(r.usize()?);
        }
        Ok(ParamEntry {
            name,
            shape,
            weight: r.f32s()?,
            m: r.f32s()?,
            v: r.f32s()?,
            t: r.u32()? as i32,
        })
    }
}

impl WireCodec for ParamStoreState {
    fn encode(&self, w: &mut ByteWriter) {
        w.u64(self.version);
        w.u32(self.entries.len() as u32);
        for e in &self.entries {
            e.encode(w);
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<ParamStoreState> {
        let version = r.u64()?;
        // Each entry holds at least a name length + shape length +
        // three vector lengths + the timestep.
        let n = r.seq_len(24)?;
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            entries.push(ParamEntry::decode(r)?);
        }
        Ok(ParamStoreState { version, entries })
    }
}

impl WireCodec for LearnableState {
    fn encode(&self, w: &mut ByteWriter) {
        w.usize(self.ty);
        w.f32s(&self.weight);
        w.f32s(&self.m);
        w.f32s(&self.v);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<LearnableState> {
        Ok(LearnableState {
            ty: r.usize()?,
            weight: r.f32s()?,
            m: r.f32s()?,
            v: r.f32s()?,
        })
    }
}

impl WireCodec for Checkpoint {
    fn encode(&self, w: &mut ByteWriter) {
        w.usize(self.epoch);
        w.u32(self.adam_t as u32);
        w.u64(self.config_hash);
        self.params.encode(w);
        w.u32(self.learnable.len() as u32);
        for l in &self.learnable {
            l.encode(w);
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<Checkpoint> {
        let epoch = r.usize()?;
        let adam_t = r.u32()? as i32;
        let config_hash = r.u64()?;
        let params = ParamStoreState::decode(r)?;
        let n = r.seq_len(20)?;
        let mut learnable = Vec::with_capacity(n);
        for _ in 0..n {
            learnable.push(LearnableState::decode(r)?);
        }
        Ok(Checkpoint {
            epoch,
            adam_t,
            config_hash,
            params,
            learnable,
        })
    }
}

/// Hash of the trajectory-relevant config: FNV-1a over the config's
/// debug form with every knob that is documented byte-identical-either-
/// way (tracing, fault injection, heartbeat timing, transport)
/// normalized away. Two configs with the same hash produce the same
/// loss trajectory, so restoring across them is sound; anything else
/// (seed, lr, staleness, topology, ...) changes the hash and makes
/// restore an error.
pub fn config_hash(cfg: &Config) -> u64 {
    let mut norm = cfg.clone();
    norm.train.trace = false;
    norm.train.fail = None;
    norm.train.hb_interval_ms = 500;
    norm.train.hb_timeout_ms = 5000;
    norm.train.transport = TransportKind::Channel;
    let text = format!("{norm:?}");
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The one checkpoint file under a checkpoint dir.
pub fn path(dir: &str) -> String {
    format!("{dir}/heta.ckpt")
}

/// Capture the full resumable state of a session, stamping `next_epoch`
/// as the epoch a restored run starts from.
pub fn capture(sess: &Session, next_epoch: usize) -> Checkpoint {
    let store = sess.store.read().unwrap_or_else(|e| e.into_inner());
    Checkpoint {
        epoch: next_epoch,
        adam_t: sess.adam_t,
        config_hash: config_hash(&sess.cfg),
        params: sess.params.export_state(),
        learnable: store.export_learnable(),
    }
}

/// Restore a session to a checkpoint's epoch boundary. The session must
/// have been built from a config whose [`config_hash`] matches — the
/// graph, features and parameters are seed-derived from it, and only
/// then does overwriting the learned state reproduce the trajectory.
pub fn restore(sess: &mut Session, ck: &Checkpoint) -> Result<()> {
    let want = config_hash(&sess.cfg);
    ensure!(
        ck.config_hash == want,
        "checkpoint was written under a different config \
         (hash {:#018x}, this session {want:#018x}) — resuming would \
         silently diverge",
        ck.config_hash
    );
    sess.params
        .restore_state(ck.params.clone())
        .context("restoring the parameter store from the checkpoint")?;
    {
        let mut store = sess.store.write().unwrap_or_else(|e| e.into_inner());
        store
            .restore_learnable(&ck.learnable)
            .context("restoring the learnable feature tables from the checkpoint")?;
    }
    sess.adam_t = ck.adam_t;
    Ok(())
}

/// Write a checkpoint atomically under `dir`: the bytes land in a temp
/// file first and replace `heta.ckpt` by rename, so a crash mid-write
/// leaves the previous checkpoint intact.
pub fn save(dir: &str, ck: &Checkpoint) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating checkpoint dir {dir}"))?;
    let mut bytes = Vec::with_capacity(6);
    bytes.extend_from_slice(&CKPT_MAGIC);
    bytes.extend_from_slice(&CODEC_VERSION.to_le_bytes());
    bytes.extend_from_slice(&encode_message(ck));
    let final_path = path(dir);
    let tmp_path = format!("{final_path}.tmp");
    std::fs::write(&tmp_path, &bytes)
        .with_context(|| format!("writing checkpoint temp file {tmp_path}"))?;
    std::fs::rename(&tmp_path, &final_path)
        .with_context(|| format!("renaming {tmp_path} over {final_path}"))?;
    Ok(())
}

/// Load the checkpoint under `dir`, if any. A missing file is
/// `Ok(None)` — `--resume` on a fresh checkpoint dir starts from
/// scratch, which makes the flag idempotent for respawned ranks. A
/// file that exists but fails the header or total-decode checks is an
/// error naming the file: a corrupt checkpoint must never silently
/// restart training from epoch 0.
pub fn load(dir: &str) -> Result<Option<Checkpoint>> {
    let p = path(dir);
    let bytes = match std::fs::read(&p) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("reading checkpoint {p}")),
    };
    if bytes.len() < 6 {
        bail!("checkpoint {p} is truncated: {} bytes, header needs 6", bytes.len());
    }
    if bytes[..4] != CKPT_MAGIC {
        bail!(
            "checkpoint {p} has wrong magic {:02x?} (want {:02x?}) — not a heta checkpoint",
            &bytes[..4],
            CKPT_MAGIC
        );
    }
    let ver = u16::from_le_bytes([bytes[4], bytes[5]]);
    if ver != CODEC_VERSION {
        bail!(
            "checkpoint {p} is codec version {ver}, this build speaks {CODEC_VERSION} — \
             re-train or use a matching build"
        );
    }
    let ck = decode_message::<Checkpoint>(&bytes[6..])
        .with_context(|| format!("decoding checkpoint {p}"))?;
    Ok(Some(ck))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Checkpoint {
        Checkpoint {
            epoch: 3,
            adam_t: 17,
            config_hash: 0xDEAD_BEEF_F00D_CAFE,
            params: ParamStoreState {
                version: 41,
                entries: vec![
                    ParamEntry {
                        name: "W_rel0".into(),
                        shape: vec![2, 3],
                        weight: vec![1.0, -2.5, f32::MIN_POSITIVE, 0.0, -0.0, 3.5],
                        m: vec![0.1; 6],
                        v: vec![0.2; 6],
                        t: 17,
                    },
                    ParamEntry {
                        name: "b".into(),
                        shape: vec![3],
                        weight: vec![0.0, 1.0, 2.0],
                        m: vec![0.0; 3],
                        v: vec![0.0; 3],
                        t: 17,
                    },
                ],
            },
            learnable: vec![LearnableState {
                ty: 1,
                weight: vec![0.5, 1.5, 2.5, 3.5],
                m: vec![0.01; 4],
                v: vec![0.02; 4],
            }],
        }
    }

    #[test]
    fn checkpoint_round_trips_bit_identically() {
        let ck = fixture();
        let bytes = encode_message(&ck);
        let back: Checkpoint = decode_message(&bytes).unwrap();
        assert_eq!(back, ck);
        // Canonical: re-encoding the decoded value gives the same bytes.
        assert_eq!(encode_message(&back), bytes);
    }

    #[test]
    fn every_truncation_is_an_error_never_a_panic() {
        let bytes = encode_message(&fixture());
        for cut in 0..bytes.len() {
            assert!(
                decode_message::<Checkpoint>(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must be rejected",
                bytes.len()
            );
        }
    }

    #[test]
    fn save_load_round_trips_and_names_corrupt_files() {
        let dir = format!(
            "{}/heta-ckpt-test-{}",
            std::env::temp_dir().display(),
            std::process::id()
        );
        let _ = std::fs::remove_dir_all(&dir);
        let ck = fixture();
        save(&dir, &ck).unwrap();
        assert!(
            !std::path::Path::new(&format!("{}.tmp", path(&dir))).exists(),
            "the temp file must be renamed away"
        );
        let back = load(&dir).unwrap().expect("checkpoint exists");
        assert_eq!(back, ck);

        // A missing checkpoint is a fresh start, not an error.
        let empty = format!("{dir}/empty");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(load(&empty).unwrap().is_none());

        // Wrong magic.
        let p = path(&dir);
        let good = std::fs::read(&p).unwrap();
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&p, &bad).unwrap();
        let err = load(&dir).unwrap_err();
        assert!(format!("{err}").contains(&p), "error must name the file: {err}");
        assert!(format!("{err}").contains("magic"), "{err}");

        // Wrong version.
        let mut bad = good.clone();
        bad[4] ^= 0xFF;
        std::fs::write(&p, &bad).unwrap();
        let err = load(&dir).unwrap_err();
        assert!(format!("{err}").contains("version"), "{err}");

        // Truncations anywhere must be errors naming the file.
        for cut in [0, 3, 5, 6, good.len() / 2, good.len() - 1] {
            std::fs::write(&p, &good[..cut]).unwrap();
            let err = load(&dir).unwrap_err();
            assert!(
                format!("{err:#}").contains(&p),
                "truncation at {cut} must name the file: {err:#}"
            );
        }

        // Trailing garbage is corrupt, not ignored.
        let mut bad = good.clone();
        bad.push(0);
        std::fs::write(&p, &bad).unwrap();
        assert!(load(&dir).is_err());

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_hash_ignores_observability_but_not_trajectory_knobs() {
        let cfg = crate::config::Config::from_json(
            &crate::util::json::parse(crate::config::TINY).unwrap(),
        )
        .unwrap();
        let base = config_hash(&cfg);

        let mut same = cfg.clone();
        same.train.trace = true;
        same.train.hb_timeout_ms = 123;
        same.train.fail = Some(FaultSpec::parse("1:2:exit").unwrap());
        assert_eq!(config_hash(&same), base, "passive knobs must not change the hash");

        let mut diff = cfg.clone();
        diff.train.seed ^= 1;
        assert_ne!(config_hash(&diff), base, "the seed is trajectory-relevant");
        let mut diff = cfg.clone();
        diff.train.staleness = 1;
        assert_ne!(config_hash(&diff), base, "staleness is trajectory-relevant");
    }
}
