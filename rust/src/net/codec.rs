//! Versioned, length-prefixed binary codec for every cluster message.
//!
//! The offline-build constraint rules out serde, so the wire format is
//! hand-rolled and deliberately boring: little-endian fixed-width
//! integers, floats as raw IEEE-754 bits (NaN payloads round-trip, so
//! decoded trajectories stay **byte-identical** to in-process runs),
//! length-prefixed sequences and UTF-8 strings. Every cluster message
//! type implements [`WireCodec`] next to its definition — the shared
//! payload structs ([`FetchStats`], [`StageTimes`], [`WorkerSpan`],
//! [`WorkerGrads`], [`ParamSnapshot`], [`StoreDelta`]) here, the
//! engine-private protocol enums in `cluster/{raf,vanilla}.rs`.
//!
//! Robustness contract: decoding never panics and never trusts a
//! length. Every read is bounds-checked against the remaining frame,
//! every declared element count is validated against the bytes that
//! could actually hold it (a corrupt length cannot trigger a huge
//! allocation), unknown enum tags are errors, and [`decode_message`]
//! rejects trailing garbage. A truncated or bit-flipped frame therefore
//! surfaces as `anyhow::Error` through the same `Result` paths a
//! mailbox hangup uses — the engines add the batch in flight.
//!
//! [`CODEC_VERSION`] is exchanged in the TCP handshake
//! (`super::tcp`); bump it whenever any message layout changes.
//!
//! [`FetchStats`]: crate::kvstore::FetchStats
//! [`StageTimes`]: crate::metrics::StageTimes
//! [`WorkerSpan`]: crate::metrics::timeline::WorkerSpan
//! [`WorkerGrads`]: crate::exec::WorkerGrads
//! [`ParamSnapshot`]: crate::runtime::ParamSnapshot
//! [`StoreDelta`]: crate::kvstore::StoreDelta

use anyhow::{bail, ensure, Result};

use crate::exec::WorkerGrads;
use crate::hetgraph::NodeId;
use crate::kvstore::{FetchStats, StoreDelta};
use crate::metrics::timeline::WorkerSpan;
use crate::metrics::StageTimes;
use crate::runtime::{ParamDiff, ParamSnapshot};

/// Version of the message layouts below, exchanged in the transport
/// handshake. Peers with different versions refuse to connect instead
/// of mis-decoding each other. v2: `Up::Obs` trace blobs on the stats
/// path and a leader timestamp in the handshake reply (PR 6). v3: the
/// reserved heartbeat lane (`tcp::LANE_HB`) and the checkpoint file
/// format of [`crate::ckpt`], which stamps this version into its
/// header (PR 7). v4: the wire-efficiency tier (PR 8) — version-chained
/// [`ParamDiff`] frames and the `NeedFull` NACK on both engines' lanes,
/// plus the worker↔worker mesh lane (`tcp::LANE_MESH_DATA`) and its
/// `MeshFwd` partial-aggregation frames.
pub const CODEC_VERSION: u16 = 4;

/// A message that can be encoded onto / decoded from a wire frame.
pub trait WireCodec: Sized {
    fn encode(&self, w: &mut ByteWriter);
    /// Decode one value. Must be total: every failure is an error, and
    /// no input may panic or over-allocate.
    fn decode(r: &mut ByteReader<'_>) -> Result<Self>;
}

/// Encode a message into a standalone byte buffer.
pub fn encode_message<T: WireCodec>(msg: &T) -> Vec<u8> {
    let mut w = ByteWriter::new();
    msg.encode(&mut w);
    w.into_bytes()
}

/// Decode a message from a complete frame, rejecting trailing bytes (a
/// frame that decodes but is longer than its message is corrupt).
pub fn decode_message<T: WireCodec>(bytes: &[u8]) -> Result<T> {
    let mut r = ByteReader::new(bytes);
    let v = T::decode(&mut r)?;
    r.finish()?;
    Ok(v)
}

// ---------------------------------------------------------------------------
// Byte-level writer/reader

/// Append-only little-endian byte sink.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as `u64` so 32- and 64-bit peers agree.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Raw IEEE-754 bits: NaNs and signed zeros round-trip exactly.
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }

    pub fn u32s(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u32(x);
        }
    }
}

/// Bounds-checked little-endian byte source over one frame.
pub struct ByteReader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(data: &'a [u8]) -> ByteReader<'a> {
        ByteReader { data, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    /// Take `n` raw bytes; errors (never panics) past the frame end.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "truncated frame: wanted {n} bytes, {} left of {}",
            self.remaining(),
            self.data.len()
        );
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Validate a declared element count against the bytes that could
    /// hold it — a corrupt length must not drive an allocation.
    pub fn seq_len(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(
            n.checked_mul(elem_bytes)
                .is_some_and(|total| total <= self.remaining()),
            "corrupt frame: sequence of {n} x {elem_bytes}B exceeds the {} bytes left",
            self.remaining()
        );
        Ok(n)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        ensure!(
            v <= usize::MAX as u64,
            "corrupt frame: {v} exceeds this platform's usize"
        );
        Ok(v as usize)
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.seq_len(1)?;
        let bytes = self.take(n)?;
        match std::str::from_utf8(bytes) {
            Ok(s) => Ok(s.to_string()),
            Err(e) => bail!("corrupt frame: invalid UTF-8 string ({e})"),
        }
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.seq_len(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.seq_len(4)?;
        (0..n).map(|_| self.u32()).collect()
    }

    /// Assert the frame was consumed exactly.
    pub fn finish(&self) -> Result<()> {
        ensure!(
            self.remaining() == 0,
            "corrupt frame: {} trailing bytes after the message",
            self.remaining()
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shared payload types (the engine protocol enums compose these; their
// own impls live next to their definitions in cluster/{raf,vanilla}.rs)

impl WireCodec for () {
    fn encode(&self, _w: &mut ByteWriter) {}
    fn decode(_r: &mut ByteReader<'_>) -> Result<()> {
        Ok(())
    }
}

impl WireCodec for FetchStats {
    fn encode(&self, w: &mut ByteWriter) {
        w.u64(self.rows);
        w.u64(self.bytes);
        w.u64(self.remote_rows);
        w.u64(self.remote_bytes);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<FetchStats> {
        Ok(FetchStats {
            rows: r.u64()?,
            bytes: r.u64()?,
            remote_rows: r.u64()?,
            remote_bytes: r.u64()?,
        })
    }
}

impl WireCodec for StageTimes {
    fn encode(&self, w: &mut ByteWriter) {
        for &s in &self.secs {
            w.f64(s);
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<StageTimes> {
        let mut secs = [0.0f64; 7];
        for s in &mut secs {
            *s = r.f64()?;
        }
        Ok(StageTimes { secs })
    }
}

impl WireCodec for WorkerSpan {
    fn encode(&self, w: &mut ByteWriter) {
        w.f64(self.sample_s);
        w.f64(self.fetch_ro_s);
        w.f64(self.fetch_lr_s);
        w.f64(self.copy_s);
        w.f64(self.fwd_s);
        w.f64(self.bwd_s);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<WorkerSpan> {
        Ok(WorkerSpan {
            sample_s: r.f64()?,
            fetch_ro_s: r.f64()?,
            fetch_lr_s: r.f64()?,
            copy_s: r.f64()?,
            fwd_s: r.f64()?,
            bwd_s: r.f64()?,
        })
    }
}

/// Epoch-relative wall-clock interval (forward/backward span).
impl WireCodec for (f64, f64) {
    fn encode(&self, w: &mut ByteWriter) {
        w.f64(self.0);
        w.f64(self.1);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<(f64, f64)> {
        Ok((r.f64()?, r.f64()?))
    }
}

impl WireCodec for WorkerGrads {
    fn encode(&self, w: &mut ByteWriter) {
        w.u32(self.wgrads.len() as u32);
        for (name, g) in &self.wgrads {
            w.str(name);
            w.f32s(g);
        }
        w.u32(self.row_grads.len() as u32);
        for (ty, ids, g) in &self.row_grads {
            w.usize(*ty);
            w.u32s(ids);
            w.f32s(g);
        }
        w.u32(self.gx.len() as u32);
        for g in &self.gx {
            w.f32s(g);
        }
        w.u32(self.learnable_rows.len() as u32);
        for &(ty, rows, remote) in &self.learnable_rows {
            w.usize(ty);
            w.u64(rows);
            w.u64(remote);
        }
        w.u64(self.param_version);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<WorkerGrads> {
        let n = r.seq_len(8)?; // each wgrad is at least a name len + vec len
        let mut wgrads = Vec::with_capacity(n);
        for _ in 0..n {
            let name = r.str()?;
            let g = r.f32s()?;
            wgrads.push((name, g));
        }
        let n = r.seq_len(16)?;
        let mut row_grads: Vec<(usize, Vec<NodeId>, Vec<f32>)> = Vec::with_capacity(n);
        for _ in 0..n {
            let ty = r.usize()?;
            let ids = r.u32s()?;
            let g = r.f32s()?;
            row_grads.push((ty, ids, g));
        }
        let n = r.seq_len(4)?;
        let mut gx = Vec::with_capacity(n);
        for _ in 0..n {
            gx.push(r.f32s()?);
        }
        let n = r.seq_len(24)?;
        let mut learnable_rows = Vec::with_capacity(n);
        for _ in 0..n {
            let ty = r.usize()?;
            let rows = r.u64()?;
            let remote = r.u64()?;
            learnable_rows.push((ty, rows, remote));
        }
        let param_version = r.u64()?;
        Ok(WorkerGrads {
            wgrads,
            row_grads,
            gx,
            learnable_rows,
            param_version,
        })
    }
}

/// Snapshots encode their tensors sorted by name, so the byte stream is
/// canonical regardless of the leader's `HashMap` iteration order.
impl WireCodec for ParamSnapshot {
    fn encode(&self, w: &mut ByteWriter) {
        w.u64(self.version);
        let tensors = self.tensors_sorted();
        w.u32(tensors.len() as u32);
        for (name, data) in tensors {
            w.str(name);
            w.f32s(data);
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<ParamSnapshot> {
        let version = r.u64()?;
        let n = r.seq_len(8)?;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name = r.str()?;
            let data = r.f32s()?;
            tensors.push((name, data));
        }
        Ok(ParamSnapshot::from_tensors(version, tensors))
    }
}

/// Diffs ship like snapshots — canonical name-sorted tensors — plus
/// the version pair that chains them: `from_version` must match the
/// receiver's last reconstructed snapshot, `to_version` stamps the
/// result. Decoding re-sorts via [`ParamDiff::from_tensors`], so a
/// non-canonical frame cannot poison downstream re-encodes.
impl WireCodec for ParamDiff {
    fn encode(&self, w: &mut ByteWriter) {
        w.u64(self.from_version);
        w.u64(self.to_version);
        let tensors = self.tensors_sorted();
        w.u32(tensors.len() as u32);
        for (name, data) in tensors {
            w.str(name);
            w.f32s(data);
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<ParamDiff> {
        let from_version = r.u64()?;
        let to_version = r.u64()?;
        ensure!(
            to_version >= from_version,
            "corrupt param diff frame: covers v{from_version}..v{to_version} \
             (the chain never runs backwards)"
        );
        let n = r.seq_len(8)?;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name = r.str()?;
            let data = r.f32s()?;
            tensors.push((name, data));
        }
        Ok(ParamDiff::from_tensors(from_version, to_version, tensors))
    }
}

impl WireCodec for StoreDelta {
    fn encode(&self, w: &mut ByteWriter) {
        w.u32(self.rows.len() as u32);
        for (ty, ids, vals) in &self.rows {
            w.usize(*ty);
            w.u32s(ids);
            w.f32s(vals);
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<StoreDelta> {
        let n = r.seq_len(16)?;
        let mut rows: Vec<(usize, Vec<NodeId>, Vec<f32>)> = Vec::with_capacity(n);
        for _ in 0..n {
            let ty = r.usize()?;
            let ids = r.u32s()?;
            let vals = r.f32s()?;
            rows.push((ty, ids, vals));
        }
        Ok(StoreDelta { rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grads_fixture() -> WorkerGrads {
        WorkerGrads {
            wgrads: vec![
                ("W1_writes".into(), vec![1.0, -2.5, f32::MIN_POSITIVE]),
                ("b".into(), vec![]),
            ],
            row_grads: vec![(3, vec![7, 9, 9, crate::sampling::PAD], vec![0.25; 8])],
            gx: vec![vec![1.5, -1.5], vec![]],
            learnable_rows: vec![(0, 12, 3), (2, 4, 0)],
            param_version: 41,
        }
    }

    #[test]
    fn primitives_round_trip() {
        let mut w = ByteWriter::new();
        w.u8(0xAB);
        w.u16(0xBEEF);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.usize(123_456);
        w.f32(-0.0);
        w.f64(f64::NAN);
        w.str("héta");
        w.f32s(&[1.0, 2.0]);
        w.u32s(&[9, 8, 7]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 0xAB);
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.usize().unwrap(), 123_456);
        let z = r.f32().unwrap();
        assert!(z == 0.0 && z.is_sign_negative(), "-0.0 must survive");
        assert!(r.f64().unwrap().is_nan(), "NaN bits must survive");
        assert_eq!(r.str().unwrap(), "héta");
        assert_eq!(r.f32s().unwrap(), vec![1.0, 2.0]);
        assert_eq!(r.u32s().unwrap(), vec![9, 8, 7]);
        r.finish().unwrap();
    }

    #[test]
    fn worker_grads_round_trip() {
        let wg = grads_fixture();
        let bytes = encode_message(&wg);
        let back: WorkerGrads = decode_message(&bytes).unwrap();
        assert_eq!(back, wg);
    }

    #[test]
    fn shared_structs_round_trip() {
        let fs = FetchStats {
            rows: 10,
            bytes: 640,
            remote_rows: 3,
            remote_bytes: 192,
        };
        assert_eq!(decode_message::<FetchStats>(&encode_message(&fs)).unwrap(), fs);

        let st = StageTimes {
            secs: [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7],
        };
        assert_eq!(decode_message::<StageTimes>(&encode_message(&st)).unwrap(), st);

        let span = WorkerSpan {
            sample_s: 1.0,
            fetch_ro_s: 2.0,
            fetch_lr_s: 3.0,
            copy_s: 4.0,
            fwd_s: 5.0,
            bwd_s: 6.0,
        };
        assert_eq!(decode_message::<WorkerSpan>(&encode_message(&span)).unwrap(), span);

        let wall = (0.25f64, 0.75f64);
        assert_eq!(decode_message::<(f64, f64)>(&encode_message(&wall)).unwrap(), wall);

        let delta = StoreDelta {
            rows: vec![(1, vec![4, 5], vec![0.5, 0.5, 1.5, 1.5])],
        };
        assert_eq!(decode_message::<StoreDelta>(&encode_message(&delta)).unwrap(), delta);

        assert_eq!(encode_message(&()).len(), 0);
        decode_message::<()>(&[]).unwrap();
    }

    #[test]
    fn every_truncation_is_an_error_never_a_panic() {
        let bytes = encode_message(&grads_fixture());
        for cut in 0..bytes.len() {
            assert!(
                decode_message::<WorkerGrads>(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must be rejected",
                bytes.len()
            );
        }
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut bytes = encode_message(&grads_fixture());
        bytes.push(0);
        let err = decode_message::<WorkerGrads>(&bytes).unwrap_err();
        assert!(
            format!("{err}").contains("trailing"),
            "trailing bytes must be named: {err}"
        );
    }

    #[test]
    fn corrupt_lengths_cannot_drive_allocations() {
        // A frame claiming 2^32-1 f32s with 4 bytes of payload must be
        // rejected by the length/remaining check, not by OOM.
        let mut w = ByteWriter::new();
        w.u32(u32::MAX);
        w.u32(7);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.f32s().is_err());
        // Same for strings.
        let mut r = ByteReader::new(&bytes);
        assert!(r.str().is_err());
    }

    #[test]
    fn invalid_utf8_is_rejected() {
        let mut w = ByteWriter::new();
        w.u32(2);
        w.u8(0xFF);
        w.u8(0xFE);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let err = r.str().unwrap_err();
        assert!(format!("{err}").contains("UTF-8"), "{err}");
    }

    #[test]
    fn param_diff_round_trips_and_rejects_backwards_chains() {
        let diff = ParamDiff::from_tensors(
            7,
            9,
            vec![
                ("zw".into(), vec![1.0, -0.0, f32::NAN]),
                ("aw".into(), vec![0.5]),
            ],
        );
        let a = encode_message(&diff);
        let b = encode_message(&diff);
        assert_eq!(a, b, "diff encoding must be canonical");
        let back: ParamDiff = decode_message(&a).unwrap();
        // NaN bits break PartialEq; compare the re-encodings instead.
        assert_eq!(encode_message(&back), a, "diff must round-trip bit-exactly");
        assert_eq!(back.from_version, 7);
        assert_eq!(back.to_version, 9);
        assert_eq!(back.tensors_sorted()[0].0, "aw", "decode keeps canonical order");

        // A chain that runs backwards is corrupt on its face.
        let mut w = ByteWriter::new();
        w.u64(9);
        w.u64(7);
        w.u32(0);
        let err = decode_message::<ParamDiff>(&w.into_bytes()).unwrap_err();
        assert!(format!("{err}").contains("backwards"), "{err}");

        // Truncations never panic.
        for cut in 0..a.len() {
            assert!(decode_message::<ParamDiff>(&a[..cut]).is_err());
        }
    }

    #[test]
    fn param_snapshot_bytes_are_canonical_and_round_trip() {
        use crate::optim::AdamParams;
        use crate::runtime::{InputSpec, ParamStore};
        let mut store = ParamStore::new(7, AdamParams::default());
        for name in ["zw", "aw", "mw"] {
            store.ensure(&InputSpec {
                kind: "weight".into(),
                shape: vec![2, 2],
                name: name.into(),
                edge: -1,
                layer: 0,
                dtype: "f32".into(),
                init: "glorot".into(),
            });
        }
        let snap = store.snapshot();
        let a = encode_message(&snap);
        let b = encode_message(&snap);
        assert_eq!(a, b, "snapshot encoding must be canonical");
        let back: ParamSnapshot = decode_message(&a).unwrap();
        assert_eq!(back, snap);
        // Sorted by name regardless of HashMap order: "aw" first.
        let mut r = ByteReader::new(&a);
        r.u64().unwrap(); // version
        r.u32().unwrap(); // count
        assert_eq!(r.str().unwrap(), "aw");
    }
}
