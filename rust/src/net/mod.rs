//! Wire transport (PR 5): multi-process cluster training over sockets
//! behind the mailbox trait.
//!
//! The cluster runtime's collectives are generic over the
//! [`Transport`](crate::cluster::mailbox::Transport) contract. Two
//! implementations exist:
//!
//! * in-process channels ([`crate::cluster::mailbox::Mailbox`]) — every
//!   rank is a thread of one process (the PR-1 runtime, still the
//!   default);
//! * the TCP star of [`tcp`] — **one OS process per rank**. The leader
//!   listens, workers dial in, and every cluster message crosses a real
//!   socket through the versioned binary codec of [`codec`].
//!
//! Which one a training run uses is the session's [`Backend`]
//! (`heta train --transport tcp --rank R --peers host:port`, or
//! `heta launch -n K` to spawn a local K-worker cluster). Every process
//! builds the same deterministic state from the config (graph, feature
//! store, parameter init, batch schedule — all seeded), so the only
//! cross-process traffic is the protocol itself: parameter snapshots
//! and batch releases down, partial aggregations and gradients up, and
//! the [`StoreDelta`](crate::kvstore::StoreDelta) broadcast that
//! replicates the leader's learnable-feature updates into every worker
//! process's KV store (in-process runs share one store and skip it).
//! Losses are **byte-identical** across `channel | tcp` at any fixed
//! staleness — the loopback half of `tests/test_net_transport.rs` pins
//! it through the shared equivalence harness.
//!
//! [`WireTraffic`] reports what actually moved: real frame bytes next
//! to the modeled bytes of the same messages
//! ([`Wire::wire_bytes`](crate::cluster::mailbox::Wire::wire_bytes)),
//! so drift between the cost model and the harness wire is visible in
//! every `EpochReport`.

pub mod codec;
pub mod tcp;

pub use codec::{decode_message, encode_message, WireCodec, CODEC_VERSION};
pub use tcp::{Role, TcpChannel, TcpNode};

/// Which transport a session's cluster runtime rides on.
pub enum Backend {
    /// In-process channels: every rank is a thread of this process.
    Channel,
    /// The socket star: this process plays exactly one rank of a
    /// multi-process cluster.
    Tcp(TcpNode),
}

impl Backend {
    /// `true` when this process is a TCP worker rank (its epoch reports
    /// carry no losses — the leader owns the trajectory).
    pub fn is_tcp_worker(&self) -> bool {
        matches!(self, Backend::Tcp(n) if n.role() != Role::Leader)
    }
}

/// The one guard every TCP entry point shares (config parse, the CLI
/// and both engines call it, so the wording can never drift): the
/// socket transport has no meaning under the sequential driver, which
/// plays every rank itself and has no peers to talk to.
pub fn require_cluster_runtime(runtime: crate::config::RuntimeKind) -> anyhow::Result<()> {
    anyhow::ensure!(
        runtime == crate::config::RuntimeKind::Cluster,
        "the tcp transport requires train.runtime = \"cluster\": the sequential \
         driver plays every rank itself and has no peers to talk to"
    );
    Ok(())
}

/// Bytes and frames a transport node actually moved, next to the
/// modeled bytes of the same messages.
///
/// * `real_*` — frame bytes on the wire, headers included (what the
///   codec produced; zero for in-process channels, which move no
///   bytes).
/// * `modeled_*` — the [`Wire::wire_bytes`] total of the same payloads:
///   the tensor bytes the *modeled* distributed system would ship
///   (snapshot distribution and control metadata are modeled-free, so
///   modeled never exceeds real for the same traffic — the loopback
///   test asserts it).
///
/// [`Wire::wire_bytes`]: crate::cluster::mailbox::Wire::wire_bytes
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireTraffic {
    pub real_sent: u64,
    pub real_recv: u64,
    pub frames_sent: u64,
    pub frames_recv: u64,
    pub modeled_sent: u64,
    pub modeled_recv: u64,
    /// Subset of `real_sent` that left on the worker↔worker mesh lane
    /// (PR 8) — zero on a plain star or a leader node.
    pub mesh_sent: u64,
    /// Subset of `real_recv` that arrived on the mesh lane.
    pub mesh_recv: u64,
}

impl WireTraffic {
    /// Traffic since an earlier snapshot of the same node (counters are
    /// cumulative across epochs).
    pub fn since(&self, earlier: &WireTraffic) -> WireTraffic {
        WireTraffic {
            real_sent: self.real_sent - earlier.real_sent,
            real_recv: self.real_recv - earlier.real_recv,
            frames_sent: self.frames_sent - earlier.frames_sent,
            frames_recv: self.frames_recv - earlier.frames_recv,
            modeled_sent: self.modeled_sent - earlier.modeled_sent,
            modeled_recv: self.modeled_recv - earlier.modeled_recv,
            mesh_sent: self.mesh_sent - earlier.mesh_sent,
            mesh_recv: self.mesh_recv - earlier.mesh_recv,
        }
    }

    pub fn merge(&mut self, o: &WireTraffic) {
        self.real_sent += o.real_sent;
        self.real_recv += o.real_recv;
        self.frames_sent += o.frames_sent;
        self.frames_recv += o.frames_recv;
        self.modeled_sent += o.modeled_sent;
        self.modeled_recv += o.modeled_recv;
        self.mesh_sent += o.mesh_sent;
        self.mesh_recv += o.mesh_recv;
    }

    pub fn real_total(&self) -> u64 {
        self.real_sent + self.real_recv
    }

    pub fn modeled_total(&self) -> u64 {
        self.modeled_sent + self.modeled_recv
    }

    pub fn frames(&self) -> u64 {
        self.frames_sent + self.frames_recv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_since_and_merge() {
        let a = WireTraffic {
            real_sent: 100,
            real_recv: 50,
            frames_sent: 4,
            frames_recv: 2,
            modeled_sent: 60,
            modeled_recv: 30,
            mesh_sent: 10,
            mesh_recv: 5,
        };
        let mut b = a;
        b.real_sent = 150;
        b.frames_sent = 6;
        b.modeled_sent = 90;
        b.mesh_sent = 25;
        let d = b.since(&a);
        assert_eq!(d.real_sent, 50);
        assert_eq!(d.frames_sent, 2);
        assert_eq!(d.modeled_sent, 30);
        assert_eq!(d.real_recv, 0);
        assert_eq!(d.mesh_sent, 15);
        assert_eq!(d.mesh_recv, 0);
        let mut m = a;
        m.merge(&d);
        assert_eq!(m, b);
        assert_eq!(b.real_total(), 200);
        assert_eq!(b.modeled_total(), 120);
        assert_eq!(b.frames(), 8);
        assert!(!Backend::Channel.is_tcp_worker());
    }
}
