//! TCP star mesh: the socket-backed transport behind the mailbox trait.
//!
//! One OS process per rank. The physical topology mirrors the logical
//! hub-and-spoke the collectives use: the leader (logical rank
//! `workers`) listens, every worker dials in, and each (worker, leader)
//! pair shares **one** full-duplex connection. All typed lanes of the
//! protocol (data up/down, barrier up/down) are multiplexed over that
//! connection with a one-byte lane id, so per-(sender, receiver) FIFO —
//! the ordering contract of [`crate::cluster::mailbox`] — is inherited
//! directly from TCP's in-order delivery: everything a process sends to
//! a peer travels one ordered stream.
//!
//! Frames are length-prefixed: `u32 len | u8 lane | payload`, with the
//! payload encoded by the message's [`WireCodec`] impl. The connection
//! handshake exchanges a magic, the [`CODEC_VERSION`] and the peer's
//! logical rank; a version mismatch refuses the connection instead of
//! mis-decoding frames.
//!
//! Failure semantics match the in-process mailbox: a peer hanging up
//! (process death, socket reset) or a frame that fails to decode
//! surfaces as `anyhow::Error` from [`TcpChannel::send`]/[`recv`] —
//! never a panic — and the engines' gather context names the batch in
//! flight. A reader thread per connection demultiplexes incoming
//! frames to per-lane queues and, on error, posts the reason to every
//! lane so a blocked receiver wakes with the root cause.
//!
//! Accounting: the node counts **real** bytes moved (frame bytes
//! actually written/read, headers included) next to the **modeled**
//! bytes of the same messages ([`Wire::wire_bytes`] — what the modeled
//! distributed system would ship). The gap between the two is the
//! codec + harness overhead `EpochReport.wire` makes visible; modeled
//! never exceeds real for the same traffic.
//!
//! Observability (PR 6): when the flight recorder is armed, each
//! reader thread records a wire-wait span per frame (parked in the
//! [`crate::obs`] sink under its own rank×thread track), both
//! directions tick per-lane byte counters
//! (`wire.lane<N>.{tx,rx}_bytes`), and the handshake reply carries the
//! leader's clock so workers can rebase their trace timestamps onto
//! the leader's timeline. All of it is gated on
//! [`crate::obs::enabled`] — an untraced run takes none of these
//! branches.
//!
//! [`recv`]: TcpChannel::recv

use std::io::{BufReader, BufWriter, Read, Write};
use std::marker::PhantomData;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::cluster::mailbox::{Envelope, Transport, Wire};
use crate::config::FaultKind;

use super::codec::{decode_message, encode_message, WireCodec, CODEC_VERSION};
use super::WireTraffic;

/// Typed lanes multiplexed over each connection. Both engines use the
/// same four data/barrier slots (one engine runs per process); the
/// fifth lane is reserved for liveness.
pub const LANE_DATA_UP: u8 = 0;
pub const LANE_DATA_DOWN: u8 = 1;
pub const LANE_BARRIER_UP: u8 = 2;
pub const LANE_BARRIER_DOWN: u8 = 3;
/// Reserved heartbeat lane (PR 7): workers write an empty frame here
/// every [`HbCfg::interval_ms`]; the leader's reader swallows it after
/// stamping the connection's last-heard clock. Heartbeat frames never
/// reach a lane queue and never touch the traffic counters — liveness
/// is not traffic.
pub const LANE_HB: u8 = 4;
/// Worker↔worker mesh lane (PR 8): RAF partial-aggregation frames flow
/// rank-to-rank here when `train.wire_exchange = mesh`, instead of
/// relaying through the leader star. Only nodes built by the
/// mesh-enabled dial/accept paths ([`dial_mesh_with`] /
/// [`accept_workers_mesh_with`]) have the sockets behind it; bytes on
/// this lane are counted separately ([`WireTraffic::mesh_sent`] /
/// `mesh_recv`) so `EpochReport.wire` can split leader vs mesh
/// traffic.
pub const LANE_MESH_DATA: u8 = 5;
const NUM_LANES: usize = 6;

/// Refuse frames beyond this size: a corrupt length prefix must not
/// drive a multi-GiB allocation. Generous next to any real message
/// (snapshots of the bench configs are a few MiB).
const MAX_FRAME_BYTES: u32 = 1 << 30;

const MAGIC: [u8; 4] = *b"HETA";

/// How long a worker keeps re-dialing a leader that has not bound its
/// listen address yet (`heta launch` starts all ranks at once).
pub const DIAL_TIMEOUT: Duration = Duration::from_secs(30);

/// Heartbeat timing of one star (`train.hb_interval_ms` /
/// `train.hb_timeout_ms`). Workers send an empty [`LANE_HB`] frame
/// every `interval_ms`; the leader declares a worker dead after
/// `timeout_ms` of total silence (any frame counts — a worker busy
/// shipping data needs no separate proof of life) and shuts the
/// connection down, turning a silent wedge into an ordinary hangup
/// error on every blocked lane. Either knob at 0 disables its side —
/// useful for tests that want a star without background timers.
#[derive(Debug, Clone, Copy)]
pub struct HbCfg {
    pub interval_ms: u64,
    pub timeout_ms: u64,
}

impl Default for HbCfg {
    fn default() -> HbCfg {
        HbCfg {
            interval_ms: 500,
            timeout_ms: 5000,
        }
    }
}

impl HbCfg {
    /// Heartbeat knobs of a config.
    pub fn from_train(t: &crate::config::TrainConfig) -> HbCfg {
        HbCfg {
            interval_ms: t.hb_interval_ms,
            timeout_ms: t.hb_timeout_ms,
        }
    }
}

/// Shared byte/frame counters of one node (all lanes, all peers).
#[derive(Default)]
struct Counters {
    real_sent: AtomicU64,
    real_recv: AtomicU64,
    frames_sent: AtomicU64,
    frames_recv: AtomicU64,
    modeled_sent: AtomicU64,
    modeled_recv: AtomicU64,
    /// Subset of `real_sent`/`real_recv` that moved on the
    /// worker↔worker mesh lane ([`LANE_MESH_DATA`]) — the split
    /// `EpochReport.wire` reports as leader vs mesh bytes.
    mesh_sent: AtomicU64,
    mesh_recv: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> WireTraffic {
        WireTraffic {
            real_sent: self.real_sent.load(Ordering::Relaxed),
            real_recv: self.real_recv.load(Ordering::Relaxed),
            frames_sent: self.frames_sent.load(Ordering::Relaxed),
            frames_recv: self.frames_recv.load(Ordering::Relaxed),
            modeled_sent: self.modeled_sent.load(Ordering::Relaxed),
            modeled_recv: self.modeled_recv.load(Ordering::Relaxed),
            mesh_sent: self.mesh_sent.load(Ordering::Relaxed),
            mesh_recv: self.mesh_recv.load(Ordering::Relaxed),
        }
    }
}

/// One raw frame routed to a lane queue; `Err` is a connection-level
/// failure (EOF, reset, corrupt header) the reader thread broadcast.
struct LaneFrame {
    from: usize,
    frame: std::result::Result<Vec<u8>, String>,
}

struct PeerConn {
    writer: Mutex<BufWriter<TcpStream>>,
}

struct NodeShared {
    /// This process's logical rank (workers `0..W`, leader `W`).
    rank: usize,
    workers: usize,
    /// Writer per logical peer rank (`None` where the star has no link,
    /// e.g. worker↔worker). `Arc` so the heartbeat-sender thread can
    /// hold the leader connection without keeping the whole node (and
    /// its teardown `Drop`) alive.
    peers: Vec<Option<Arc<PeerConn>>>,
    /// Per-lane frame queues, taken once by [`TcpNode::open_lane`].
    lane_rx: Mutex<Vec<Option<Receiver<LaneFrame>>>>,
    counters: Arc<Counters>,
    /// Node teardown flag: the heartbeat sender and monitor threads
    /// exit their sleep loops once this is set.
    closed: Arc<AtomicBool>,
    /// Fault injection ([`FaultKind::Stall`]): a stalled worker stops
    /// proving liveness, so the leader's timeout — not a clean error —
    /// detects it.
    hb_paused: Arc<AtomicBool>,
    /// Fault injection ([`FaultKind::CorruptFrame`]): the next outbound
    /// frame's body gets a bit flipped before it hits the wire.
    corrupt_next: AtomicBool,
    /// Raw handles for teardown: shutting the sockets down unblocks the
    /// reader threads (which hold fd clones that would otherwise keep
    /// the connections alive forever).
    raw: Vec<TcpStream>,
}

impl Drop for NodeShared {
    fn drop(&mut self) {
        self.closed.store(true, Ordering::SeqCst);
        for s in &self.raw {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

/// One process's endpoint of the TCP star.
pub struct TcpNode {
    shared: Arc<NodeShared>,
}

/// Which protocol role this process's rank plays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Leader,
    Worker(usize),
}

impl TcpNode {
    /// Logical rank of this process.
    pub fn rank(&self) -> usize {
        self.shared.rank
    }

    /// Number of worker ranks in the star (the leader is rank
    /// `workers`).
    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    pub fn role(&self) -> Role {
        if self.shared.rank == self.shared.workers {
            Role::Leader
        } else {
            Role::Worker(self.shared.rank)
        }
    }

    /// Cumulative traffic of this node since connection (all lanes).
    pub fn traffic(&self) -> WireTraffic {
        self.shared.counters.snapshot()
    }

    /// Take the typed endpoint of one lane. Each lane's receive queue
    /// exists once; opening the same lane twice is an error (the
    /// engines open their lanes once per training run and reuse them
    /// across epochs).
    pub fn open_lane<T: WireCodec + Wire>(&self, lane: u8) -> Result<TcpChannel<T>> {
        let mut lanes = lock(&self.shared.lane_rx);
        let slot = lanes
            .get_mut(lane as usize)
            .ok_or_else(|| anyhow!("lane {lane} outside the {NUM_LANES}-lane table"))?;
        let rx = slot
            .take()
            .ok_or_else(|| anyhow!("lane {lane} already opened by this process"))?;
        Ok(TcpChannel {
            shared: Arc::clone(&self.shared),
            lane,
            rx,
            _payload: PhantomData,
        })
    }

    /// Tear the node's connections down now (fault injection / early
    /// shutdown): every blocked peer sees an ordinary hangup error.
    pub fn shutdown(&self) {
        self.shared.closed.store(true, Ordering::SeqCst);
        for s in &self.shared.raw {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    /// Stop proving liveness (fault injection: [`FaultKind::Stall`]).
    /// The node keeps its sockets; only the heartbeat sender goes
    /// silent, so detection must come from the leader's timeout.
    pub fn pause_heartbeats(&self) {
        self.shared.hb_paused.store(true, Ordering::SeqCst);
    }

    /// Bit-flip the body of this node's next outbound frame (fault
    /// injection: [`FaultKind::CorruptFrame`]). The framing stays
    /// intact — the receiver's total decode, not the stream sync, must
    /// catch it.
    pub fn inject_corrupt_frame(&self) {
        self.shared.corrupt_next.store(true, Ordering::SeqCst);
    }
}

/// Mutex helper: these locks guard plain data, so a poisoned lock (a
/// panicking peer thread) is re-entered rather than propagated.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The typed endpoint of one lane on one node: the socket-backed
/// implementation of the mailbox [`Transport`] contract.
pub struct TcpChannel<T> {
    shared: Arc<NodeShared>,
    lane: u8,
    rx: Receiver<LaneFrame>,
    _payload: PhantomData<fn() -> T>,
}

impl<T> TcpChannel<T> {
    /// Node-level traffic counters (shared by every lane of this
    /// process — sum across lanes would double count).
    pub fn traffic(&self) -> WireTraffic {
        self.shared.counters.snapshot()
    }

    /// The connection toward logical rank `to`, with the errors both
    /// send paths share.
    fn conn(&self, to: usize) -> Result<&Arc<PeerConn>> {
        self.shared
            .peers
            .get(to)
            .ok_or_else(|| {
                anyhow!("rank {to} outside this {}-worker star", self.shared.workers)
            })?
            .as_ref()
            .ok_or_else(|| {
                anyhow!(
                    "no socket from rank {} to rank {to} (the star links workers \
                     to the leader only; worker↔worker sockets exist only on a \
                     mesh-built node)",
                    self.shared.rank
                )
            })
    }

    /// Write one already-encoded frame to `conn` and account for it.
    /// Shared by [`Transport::send`] (one encode, one write) and the
    /// encode-once [`Transport::broadcast_encoded`] (one encode, K
    /// writes): counters tick **per write**, so frame counts stay
    /// exact either way.
    fn write_frame(&self, to: usize, conn: &PeerConn, body: &[u8]) -> Result<()> {
        // Check before the u32 cast: a >= 4 GiB body must not wrap into
        // a small length that desyncs the stream.
        ensure!(
            body.len() + 1 <= MAX_FRAME_BYTES as usize,
            "frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte limit",
            body.len() + 1
        );
        let len = (body.len() + 1) as u32;
        {
            let mut w = lock(&conn.writer);
            (|| -> std::io::Result<()> {
                w.write_all(&len.to_le_bytes())?;
                w.write_all(&[self.lane])?;
                w.write_all(body)?;
                w.flush()
            })()
            .map_err(|e| {
                anyhow!(
                    "rank {to} hung up (socket write failed: {e}; peer process exited early?)"
                )
            })?;
        }
        let c = &self.shared.counters;
        c.real_sent.fetch_add(4 + len as u64, Ordering::Relaxed);
        c.frames_sent.fetch_add(1, Ordering::Relaxed);
        if self.lane == LANE_MESH_DATA {
            c.mesh_sent.fetch_add(4 + len as u64, Ordering::Relaxed);
        }
        if crate::obs::enabled() {
            crate::obs::counter_add(&format!("wire.lane{}.tx_bytes", self.lane), 4 + len as u64);
        }
        Ok(())
    }
}

/// Fault injection ([`FaultKind::CorruptFrame`]): flip the tag/top bit
/// so the receiver's decode deterministically rejects the frame (an
/// unknown enum tag), or append trailing garbage when the body is
/// empty. The frame header stays valid — the stream must not desync,
/// the *message* must fail its total decode.
fn mangle_body(body: &mut Vec<u8>) {
    match body.first_mut() {
        Some(b) => *b ^= 0x80,
        None => body.push(0xFF),
    }
}

impl<T: WireCodec + Wire> Transport<T> for TcpChannel<T> {
    fn rank(&self) -> usize {
        self.shared.rank
    }

    fn send(&self, to: usize, payload: T) -> Result<()> {
        let conn = self.conn(to)?;
        let mut body = encode_message(&payload);
        if self.shared.corrupt_next.swap(false, Ordering::SeqCst) {
            mangle_body(&mut body);
        }
        self.write_frame(to, conn, &body)?;
        self.shared
            .counters
            .modeled_sent
            .fetch_add(payload.wire_bytes(), Ordering::Relaxed);
        Ok(())
    }

    /// Encode-once broadcast: serialize the frame exactly once and
    /// write the same bytes to every worker connection — the leader's
    /// per-batch snapshot release costs one encode instead of K. The
    /// one-shot [`FaultKind::CorruptFrame`] armament corrupts exactly
    /// one copy (worker 0's), matching the single-frame semantics of
    /// the per-peer path.
    fn broadcast_encoded(&self, workers: usize, payload: &T) -> Result<()>
    where
        T: Clone,
    {
        let body = encode_message(payload);
        let wire = payload.wire_bytes();
        let corrupt_first = self.shared.corrupt_next.swap(false, Ordering::SeqCst);
        for w in 0..workers {
            let conn = self.conn(w)?;
            if w == 0 && corrupt_first {
                let mut mangled = body.clone();
                mangle_body(&mut mangled);
                self.write_frame(w, conn, &mangled)?;
            } else {
                self.write_frame(w, conn, &body)?;
            }
            self.shared
                .counters
                .modeled_sent
                .fetch_add(wire, Ordering::Relaxed);
        }
        Ok(())
    }

    fn recv(&self) -> Result<Envelope<T>> {
        let f = self.rx.recv().map_err(|_| {
            anyhow!(
                "all peers of rank {} hung up (every connection closed mid-run)",
                self.shared.rank
            )
        })?;
        let bytes = match f.frame {
            Ok(b) => b,
            Err(reason) => bail!(
                "rank {} hung up while rank {} waited on lane {}: {reason}",
                f.from,
                self.shared.rank,
                self.lane
            ),
        };
        let payload: T = decode_message(&bytes).with_context(|| {
            format!(
                "decoding a lane-{} frame of {} bytes from rank {}",
                self.lane,
                bytes.len(),
                f.from
            )
        })?;
        self.shared
            .counters
            .modeled_recv
            .fetch_add(payload.wire_bytes(), Ordering::Relaxed);
        Ok(Envelope {
            from: f.from,
            payload,
        })
    }

    /// Deterministic fault injection on the real transport: the
    /// in-process channel transport has nothing to sabotage (its trait
    /// default is a no-op), but over TCP the kinds map to real
    /// machinery — see [`FaultKind`].
    fn sabotage(&self, kind: FaultKind) {
        match kind {
            // A process exit needs no socket help: the faulted rank
            // bails out of its epoch and its teardown closes the star.
            FaultKind::Exit => {}
            FaultKind::Stall => {
                self.shared.hb_paused.store(true, Ordering::SeqCst);
            }
            FaultKind::DropConn => {
                self.shared.closed.store(true, Ordering::SeqCst);
                for s in &self.shared.raw {
                    let _ = s.shutdown(Shutdown::Both);
                }
            }
            FaultKind::CorruptFrame => {
                self.shared.corrupt_next.store(true, Ordering::SeqCst);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Connection setup

fn handshake_bytes(rank: u16) -> [u8; 8] {
    let v = CODEC_VERSION.to_le_bytes();
    let r = rank.to_le_bytes();
    [MAGIC[0], MAGIC[1], MAGIC[2], MAGIC[3], v[0], v[1], r[0], r[1]]
}

fn read_handshake(stream: &mut TcpStream, who: &str) -> Result<u16> {
    let mut buf = [0u8; 8];
    stream
        .read_exact(&mut buf)
        .with_context(|| format!("reading the handshake from {who}"))?;
    ensure!(
        buf[..4] == MAGIC,
        "{who} is not a heta transport peer (bad magic {:02x?})",
        &buf[..4]
    );
    let ver = u16::from_le_bytes([buf[4], buf[5]]);
    ensure!(
        ver == CODEC_VERSION,
        "{who} speaks codec version {ver}, this build speaks {CODEC_VERSION} \
         (mixed builds cannot exchange frames)"
    );
    Ok(u16::from_le_bytes([buf[6], buf[7]]))
}

fn configure(stream: &TcpStream) -> Result<()> {
    // The protocol is latency-bound (2·[B,H] tensors per hop); never
    // let Nagle batch a release against a gather.
    stream.set_nodelay(true).context("set_nodelay")?;
    Ok(())
}

/// Finish building a node over its established connections:
/// `conns[i] = (peer logical rank, stream)`. Besides the per-connection
/// reader threads this spawns the liveness machinery of `hb`: workers
/// get a heartbeat-sender thread toward the leader, the leader gets one
/// monitor thread watching every connection's last-heard clock.
fn build_node(
    rank: usize,
    workers: usize,
    conns: Vec<(usize, TcpStream)>,
    hb: HbCfg,
) -> Result<TcpNode> {
    let counters = Arc::new(Counters::default());
    let closed = Arc::new(AtomicBool::new(false));
    let hb_paused = Arc::new(AtomicBool::new(false));
    let (lane_tx, lane_rx): (Vec<Sender<LaneFrame>>, Vec<Option<Receiver<LaneFrame>>>) = (0
        ..NUM_LANES)
        .map(|_| {
            let (tx, rx) = channel();
            (tx, Some(rx))
        })
        .unzip();
    let is_leader = rank == workers;
    let mut peers: Vec<Option<Arc<PeerConn>>> = (0..workers + 1).map(|_| None).collect();
    let mut raw = Vec::with_capacity(conns.len());
    // (peer rank, shutdown handle, last-heard clock, timed-out flag) per
    // connection the leader's monitor thread watches.
    let mut watch: Vec<(usize, TcpStream, Arc<AtomicU64>, Arc<AtomicBool>)> = Vec::new();
    for (peer, stream) in conns {
        ensure!(peers[peer].is_none(), "duplicate connection from rank {peer}");
        let read_half = stream.try_clone().context("cloning the socket read half")?;
        raw.push(stream.try_clone().context("cloning the shutdown handle")?);
        let last_heard = Arc::new(AtomicU64::new(crate::obs::now_us()));
        let timed_out = Arc::new(AtomicBool::new(false));
        // Share the liveness stamps with /healthz (no-op unless the
        // telemetry plane is armed via --metrics-addr).
        crate::obs::health_register_peer(peer, Arc::clone(&last_heard), Arc::clone(&timed_out));
        if is_leader && hb.timeout_ms > 0 {
            watch.push((
                peer,
                stream.try_clone().context("cloning the monitor handle")?,
                Arc::clone(&last_heard),
                Arc::clone(&timed_out),
            ));
        }
        let senders: Vec<Sender<LaneFrame>> = lane_tx.clone();
        let c = Arc::clone(&counters);
        std::thread::Builder::new()
            .name(format!("net-rx-{rank}-from-{peer}"))
            .spawn(move || reader_loop(read_half, rank, peer, senders, c, last_heard, timed_out))
            .context("spawning the connection reader thread")?;
        peers[peer] = Some(Arc::new(PeerConn {
            writer: Mutex::new(BufWriter::new(stream)),
        }));
    }
    if is_leader && hb.timeout_ms > 0 {
        let closed = Arc::clone(&closed);
        let timeout_us = hb.timeout_ms.saturating_mul(1000);
        let check_ms = hb.interval_ms.clamp(10, 500);
        std::thread::Builder::new()
            .name(format!("net-hb-monitor-{rank}"))
            .spawn(move || {
                while !closed.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(check_ms));
                    for (peer, stream, last_heard, timed_out) in &watch {
                        let silent = crate::obs::now_us()
                            .saturating_sub(last_heard.load(Ordering::SeqCst));
                        // Degrading-signal gauges (PR 10): a silent
                        // rank shows up as a climbing last-heard lag
                        // long before the terminal hangup. The enabled
                        // gate keeps the untraced monitor allocation-
                        // free; gauge_set re-checks it internally.
                        if crate::obs::enabled() {
                            crate::obs::gauge_set(
                                &format!("hb.rank{peer}.last_heard_ms"),
                                silent as f64 / 1000.0,
                            );
                        }
                        if silent > timeout_us && !timed_out.swap(true, Ordering::SeqCst) {
                            crate::obs::counter_add("hb.missed_deadlines", 1);
                            crate::log!(
                                Warn,
                                "leader: declaring rank {peer} dead — silent for \
                                 {silent}us (heartbeat timeout {}ms); shutting its \
                                 connection down",
                                timeout_us / 1000
                            );
                            let _ = stream.shutdown(Shutdown::Both);
                        }
                    }
                }
            })
            .context("spawning the heartbeat monitor thread")?;
    }
    if !is_leader && hb.interval_ms > 0 {
        // The sender holds only the leader connection + flags, so the
        // node's teardown `Drop` (which sets `closed`) still runs when
        // the last `TcpNode`/`TcpChannel` handle goes away.
        let conn = Arc::clone(peers[workers].as_ref().ok_or_else(|| {
            anyhow!("worker {rank} built without a leader connection")
        })?);
        let closed = Arc::clone(&closed);
        let paused = Arc::clone(&hb_paused);
        std::thread::Builder::new()
            .name(format!("net-hb-sender-{rank}"))
            .spawn(move || {
                loop {
                    std::thread::sleep(Duration::from_millis(hb.interval_ms));
                    if closed.load(Ordering::SeqCst) {
                        break;
                    }
                    if paused.load(Ordering::SeqCst) {
                        continue;
                    }
                    // Raw empty frame on the reserved lane, skipping the
                    // traffic counters on both ends: liveness is not
                    // traffic, and exact frame counts stay meaningful.
                    let mut w = lock(&conn.writer);
                    let res = (|| -> std::io::Result<()> {
                        w.write_all(&1u32.to_le_bytes())?;
                        w.write_all(&[LANE_HB])?;
                        w.flush()
                    })();
                    if res.is_err() {
                        break; // connection gone; the reader reports it
                    }
                    // Worker-side hb.* family for /metrics: proof-of-
                    // life beats sent (gated internally; liveness
                    // frames still skip the wire.lane* traffic
                    // counters — liveness is not traffic).
                    crate::obs::counter_add("hb.sent_total", 1);
                }
            })
            .context("spawning the heartbeat sender thread")?;
    }
    Ok(TcpNode {
        shared: Arc::new(NodeShared {
            rank,
            workers,
            peers,
            lane_rx: Mutex::new(lane_rx),
            counters,
            closed,
            hb_paused,
            corrupt_next: AtomicBool::new(false),
            raw,
        }),
    })
}

/// Lane names for the reader-thread trace tracks, indexed by lane id.
const RX_LANE_NAMES: [&str; NUM_LANES] =
    ["rx-lane0", "rx-lane1", "rx-lane2", "rx-lane3", "rx-lane4", "rx-lane5"];

/// Park this reader's recorded frame spans in the obs sink as one
/// track; the next epoch-end [`crate::obs::TraceBlob::collect`] on
/// this process picks them up.
fn flush_rx_events(rank: usize, from: usize, events: &mut Vec<crate::obs::ObsEvent>) {
    if events.is_empty() {
        return;
    }
    crate::obs::sink_push(crate::obs::TraceTrack {
        rank: rank as u32,
        thread: format!("net-rx-from-{from}"),
        dropped: 0,
        names: RX_LANE_NAMES.iter().map(|s| s.to_string()).collect(),
        events: std::mem::take(events),
    });
}

/// Demultiplex one connection: read frames, route them to their lane
/// queues, and on any failure broadcast the reason to every lane so a
/// blocked receiver wakes with the root cause instead of hanging.
fn reader_loop(
    stream: TcpStream,
    rank: usize,
    from: usize,
    senders: Vec<Sender<LaneFrame>>,
    counters: Arc<Counters>,
    last_heard: Arc<AtomicU64>,
    timed_out: Arc<AtomicBool>,
) {
    let mut r = BufReader::new(stream);
    // Frame spans recorded while the flight recorder is armed; the
    // reader threads outlive epochs, so these flush into the global
    // sink instead of a thread-registered buffer.
    let mut rx_events: Vec<crate::obs::ObsEvent> = Vec::new();
    let reason = loop {
        let t0_us = if crate::obs::enabled() { crate::obs::now_us() } else { 0 };
        let mut hdr = [0u8; 4];
        if let Err(e) = r.read_exact(&mut hdr) {
            break if e.kind() == std::io::ErrorKind::UnexpectedEof {
                format!("rank {from} closed its connection")
            } else {
                format!("reading from rank {from} failed: {e}")
            };
        }
        let len = u32::from_le_bytes(hdr);
        if len == 0 || len > MAX_FRAME_BYTES {
            break format!("corrupt frame header from rank {from} (length {len})");
        }
        let mut lane = [0u8; 1];
        if let Err(e) = r.read_exact(&mut lane) {
            break format!("reading a frame lane from rank {from} failed: {e}");
        }
        let mut body = vec![0u8; len as usize - 1];
        if let Err(e) = r.read_exact(&mut body) {
            break format!("reading a {len}-byte frame from rank {from} failed: {e}");
        }
        // Every complete frame proves the peer alive — data counts as
        // much as a dedicated heartbeat.
        last_heard.store(crate::obs::now_us(), Ordering::SeqCst);
        if lane[0] == LANE_HB {
            // Liveness-only frame: swallowed here, no counters, no
            // lane queue, no trace span.
            continue;
        }
        counters.real_recv.fetch_add(4 + len as u64, Ordering::Relaxed);
        counters.frames_recv.fetch_add(1, Ordering::Relaxed);
        if lane[0] == LANE_MESH_DATA {
            counters.mesh_recv.fetch_add(4 + len as u64, Ordering::Relaxed);
        }
        if crate::obs::enabled() && (lane[0] as usize) < NUM_LANES {
            crate::obs::counter_add(&format!("wire.lane{}.rx_bytes", lane[0]), 4 + len as u64);
            rx_events.push(crate::obs::ObsEvent {
                batch: crate::obs::NO_BATCH_U64,
                kind: crate::obs::KIND_WIRE_WAIT,
                lane: lane[0],
                name_idx: lane[0] as u16,
                t0_us,
                t1_us: crate::obs::now_us(),
            });
            // Barrier frames bracket epochs, so flushing on them keeps
            // the sink roughly epoch-fresh; the size cap bounds memory
            // between barriers. (Events still buffered when an epoch's
            // blob is collected surface in the next collection.)
            if rx_events.len() >= 64 || lane[0] >= LANE_BARRIER_UP {
                flush_rx_events(rank, from, &mut rx_events);
            }
        }
        let Some(tx) = senders.get(lane[0] as usize) else {
            break format!("frame from rank {from} names unknown lane {}", lane[0]);
        };
        // A dropped lane receiver just means nobody is listening there
        // anymore (epoch teardown); not an error.
        let _ = tx.send(LaneFrame {
            from,
            frame: Ok(body),
        });
    };
    flush_rx_events(rank, from, &mut rx_events);
    // When the monitor killed this connection, the read error above is
    // just the symptom; name the real cause on every lane.
    let reason = if timed_out.load(Ordering::SeqCst) {
        format!("rank {from} missed its heartbeat deadline and was declared dead ({reason})")
    } else {
        reason
    };
    for tx in &senders {
        let _ = tx.send(LaneFrame {
            from,
            frame: Err(reason.clone()),
        });
    }
}

/// Leader side: bind `addr` and accept every worker's dial-in.
pub fn listen(addr: &str, workers: usize) -> Result<TcpNode> {
    listen_with(addr, workers, HbCfg::default())
}

/// [`listen`] with explicit heartbeat timing.
pub fn listen_with(addr: &str, workers: usize, hb: HbCfg) -> Result<TcpNode> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("leader binding the listen address {addr}"))?;
    accept_workers_with(listener, workers, hb)
}

/// How long a dialer gets to complete its handshake before the leader
/// drops the connection and keeps accepting (a stray port probe that
/// connects and sends nothing must not deadlock cluster startup).
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Overall deadline for the full worker set to dial in. A worker that
/// died before dialing (crash, bad spawn, duplicate --rank) must not
/// hang the leader — and everything reaping it — forever; generous
/// enough for ranks started by hand across terminals.
pub const ACCEPT_TIMEOUT: Duration = Duration::from_secs(180);

/// Leader side over an already-bound listener (lets callers bind port 0
/// and learn the ephemeral address before workers dial).
///
/// Robustness: a dial-in that fails its handshake — bad magic (port
/// scanner, health-check probe), codec-version mismatch, out-of-range
/// or duplicate rank, or silence past [`HANDSHAKE_TIMEOUT`] — is
/// logged, dropped, and the leader keeps accepting. The rejected
/// dialer sees EOF and errors on its side; only the listener socket
/// itself failing aborts the cluster.
pub fn accept_workers(listener: TcpListener, workers: usize) -> Result<TcpNode> {
    accept_workers_with(listener, workers, HbCfg::default())
}

/// [`accept_workers`] with explicit heartbeat timing.
pub fn accept_workers_with(listener: TcpListener, workers: usize, hb: HbCfg) -> Result<TcpNode> {
    accept_workers_impl(listener, workers, hb, false)
}

/// Leader side of a **mesh-enabled** star: accept every worker as
/// usual, then broker the worker↔worker mesh — gather each worker's
/// mesh listen address over its star connection and broadcast the full
/// table back, so workers can dial each other by rank order. The
/// leader itself holds no mesh sockets; its star topology (and byte
/// accounting) is unchanged.
pub fn accept_workers_mesh_with(
    listener: TcpListener,
    workers: usize,
    hb: HbCfg,
) -> Result<TcpNode> {
    accept_workers_impl(listener, workers, hb, true)
}

/// [`listen_with`] for a mesh-enabled star.
pub fn listen_mesh_with(addr: &str, workers: usize, hb: HbCfg) -> Result<TcpNode> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("leader binding the listen address {addr}"))?;
    accept_workers_mesh_with(listener, workers, hb)
}

fn accept_workers_impl(
    listener: TcpListener,
    workers: usize,
    hb: HbCfg,
    mesh: bool,
) -> Result<TcpNode> {
    ensure!(workers >= 1, "a star needs at least one worker rank");
    // Poll the listener against an overall deadline: `TcpListener` has
    // no accept timeout, and blocking forever on a worker that died
    // before dialing would hang the whole launch.
    listener
        .set_nonblocking(true)
        .context("arming the accept deadline")?;
    let deadline = Instant::now() + ACCEPT_TIMEOUT;
    let mut conns: Vec<Option<(usize, TcpStream)>> = (0..workers).map(|_| None).collect();
    let mut connected = 0usize;
    while connected < workers {
        let (mut stream, peer_addr) = match listener.accept() {
            Ok(pair) => pair,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!(
                        "only {connected} of {workers} workers dialed in within \
                         {ACCEPT_TIMEOUT:?} — a worker rank died before dialing, or its \
                         --peers/--rank point elsewhere"
                    );
                }
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
            Err(e) => return Err(e).context("accepting a worker dial-in"),
        };
        // Accepted sockets may inherit the listener's non-blocking mode
        // on some platforms; the handshake and reader threads need
        // blocking reads.
        stream
            .set_nonblocking(false)
            .context("restoring blocking mode on an accepted socket")?;
        let taken: Vec<bool> = conns.iter().map(|c| c.is_some()).collect();
        match admit_worker(&mut stream, &peer_addr.to_string(), workers, &taken) {
            Ok(w) => {
                conns[w] = Some((w, stream));
                connected += 1;
            }
            Err(e) => {
                crate::log!(
                    Warn,
                    "leader: rejected dial-in from {peer_addr} ({e:#}); still waiting for \
                     {} of {workers} workers",
                    workers - connected
                );
            }
        }
    }
    let mut conns: Vec<(usize, TcpStream)> = conns.into_iter().flatten().collect();
    if mesh {
        broker_mesh_table(&mut conns, workers)?;
    }
    build_node(workers, workers, conns, hb)
}

/// Hard cap on one announced mesh address ("host:port"); a corrupt
/// length prefix must not drive an allocation.
const MESH_ADDR_CAP: usize = 256;

/// Write one `u32 len | bytes` blob (the raw-stream framing the mesh
/// brokerage uses before any lane machinery exists).
fn write_blob(stream: &mut TcpStream, bytes: &[u8]) -> std::io::Result<()> {
    stream.write_all(&(bytes.len() as u32).to_le_bytes())?;
    stream.write_all(bytes)?;
    stream.flush()
}

/// Read one `u32 len | bytes` blob, capped.
fn read_blob(stream: &mut TcpStream, cap: usize, what: &str) -> Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    stream
        .read_exact(&mut hdr)
        .with_context(|| format!("reading the length of {what}"))?;
    let len = u32::from_le_bytes(hdr) as usize;
    ensure!(len <= cap, "{what}: a {len}-byte blob exceeds the {cap}-byte cap");
    let mut buf = vec![0u8; len];
    stream
        .read_exact(&mut buf)
        .with_context(|| format!("reading {what} ({len} bytes)"))?;
    Ok(buf)
}

/// Leader half of the mesh brokerage: read every worker's announced
/// mesh listen address (in rank order — each worker sends it right
/// after its handshake, so the streams already buffer them), then
/// broadcast the complete rank→address table to every worker.
fn broker_mesh_table(conns: &mut [(usize, TcpStream)], workers: usize) -> Result<()> {
    let mut addrs: Vec<String> = vec![String::new(); workers];
    for (w, stream) in conns.iter_mut() {
        stream
            .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
            .context("arming the mesh-address timeout")?;
        let blob = read_blob(
            stream,
            MESH_ADDR_CAP,
            &format!("worker {w}'s mesh listen address"),
        )?;
        let addr = std::str::from_utf8(&blob)
            .map_err(|e| anyhow!("worker {w}'s mesh address is not UTF-8 ({e})"))?;
        stream
            .set_read_timeout(None)
            .context("disarming the mesh-address timeout")?;
        addrs[*w] = addr.to_string();
    }
    let mut table = super::codec::ByteWriter::new();
    table.u32(workers as u32);
    for a in &addrs {
        table.str(a);
    }
    let table = table.into_bytes();
    for (w, stream) in conns.iter_mut() {
        write_blob(stream, &table)
            .with_context(|| format!("sending the mesh table to worker {w}"))?;
    }
    Ok(())
}

/// Decode the rank→address table the leader brokered.
fn parse_mesh_table(bytes: &[u8], workers: usize) -> Result<Vec<String>> {
    let mut r = super::codec::ByteReader::new(bytes);
    let n = r.u32()? as usize;
    ensure!(
        n == workers,
        "mesh table lists {n} workers, this star has {workers}"
    );
    let addrs: Vec<String> = (0..n).map(|_| r.str()).collect::<Result<_>>()?;
    r.finish().context("decoding the mesh address table")?;
    Ok(addrs)
}

/// Worker half of the mesh brokerage plus the dial-by-rank-order mesh
/// itself: bind an ephemeral listener, announce it to the leader, read
/// the brokered table, then **dial every lower rank and accept every
/// higher rank** — a total order on connection initiative, so the mesh
/// forms without symmetry-breaking races. Returns the established
/// worker↔worker connections (peer rank, stream).
fn mesh_join(
    leader_stream: &mut TcpStream,
    worker: usize,
    workers: usize,
) -> Result<Vec<(usize, TcpStream)>> {
    let ip = leader_stream
        .local_addr()
        .context("mesh: reading the local address of the leader link")?
        .ip();
    let listener = TcpListener::bind((ip, 0))
        .with_context(|| format!("worker {worker} binding its mesh listener on {ip}"))?;
    let my_addr = listener
        .local_addr()
        .context("mesh listener address")?
        .to_string();
    ensure!(
        my_addr.len() <= MESH_ADDR_CAP,
        "mesh listen address '{my_addr}' exceeds the {MESH_ADDR_CAP}-byte cap"
    );
    write_blob(leader_stream, my_addr.as_bytes())
        .with_context(|| format!("worker {worker} announcing its mesh address"))?;
    // The table only comes back once ALL workers dialed the leader, so
    // this wait gets the accept deadline, not the handshake one.
    leader_stream
        .set_read_timeout(Some(ACCEPT_TIMEOUT))
        .context("arming the mesh-table timeout")?;
    let table = read_blob(
        leader_stream,
        4 + workers * (MESH_ADDR_CAP + 4),
        "the mesh address table",
    )?;
    leader_stream
        .set_read_timeout(None)
        .context("disarming the mesh-table timeout")?;
    let addrs = parse_mesh_table(&table, workers)?;
    let mut conns: Vec<(usize, TcpStream)> = Vec::with_capacity(workers.saturating_sub(1));
    // Dial phase: every lower rank. Their listeners were bound before
    // the table was brokered, so the backlog holds us even if the peer
    // is still in its own dial phase.
    for (p, addr) in addrs.iter().enumerate().take(worker) {
        let deadline = Instant::now() + DIAL_TIMEOUT;
        let mut backoff = Duration::from_millis(25);
        let mut stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        bail!(
                            "worker {worker} could not reach mesh peer {p} at {addr} \
                             within {DIAL_TIMEOUT:?}: {e}"
                        );
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(500));
                }
            }
        };
        configure(&stream)?;
        stream
            .write_all(&handshake_bytes(worker as u16))
            .and_then(|_| stream.flush())
            .with_context(|| format!("worker {worker} greeting mesh peer {p}"))?;
        stream
            .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
            .context("arming the mesh-handshake timeout")?;
        let got = read_handshake(&mut stream, &format!("mesh peer {p} at {addr}"))? as usize;
        ensure!(
            got == p,
            "mesh peer at {addr} answered as rank {got}, the table lists rank {p}"
        );
        stream
            .set_read_timeout(None)
            .context("disarming the mesh-handshake timeout")?;
        conns.push((p, stream));
    }
    // Accept phase: every higher rank dials us. Same robustness rules
    // as the leader's accept loop — a bad dial-in is rejected and
    // logged, not fatal.
    listener
        .set_nonblocking(true)
        .context("arming the mesh accept deadline")?;
    let deadline = Instant::now() + ACCEPT_TIMEOUT;
    let mut taken: Vec<bool> = vec![false; workers];
    let mut pending = workers - worker - 1;
    while pending > 0 {
        let (mut stream, peer_addr) = match listener.accept() {
            Ok(pair) => pair,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!(
                        "worker {worker}: only {} of {} higher-ranked mesh peers dialed \
                         in within {ACCEPT_TIMEOUT:?}",
                        workers - worker - 1 - pending,
                        workers - worker - 1
                    );
                }
                std::thread::sleep(Duration::from_millis(25));
                continue;
            }
            Err(e) => return Err(e).context("accepting a mesh dial-in"),
        };
        stream
            .set_nonblocking(false)
            .context("restoring blocking mode on an accepted mesh socket")?;
        let admitted = (|| -> Result<usize> {
            configure(&stream)?;
            stream
                .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
                .context("arming the mesh-handshake timeout")?;
            let q = read_handshake(&mut stream, &format!("mesh dialer {peer_addr}"))? as usize;
            ensure!(
                q > worker && q < workers,
                "mesh dialer {peer_addr} claims rank {q}; rank {worker} only accepts \
                 higher worker ranks (dial-by-rank-order)"
            );
            ensure!(!taken[q], "two mesh dialers claim rank {q}");
            stream
                .write_all(&handshake_bytes(worker as u16))
                .and_then(|_| stream.flush())
                .with_context(|| format!("answering mesh peer {q}"))?;
            stream
                .set_read_timeout(None)
                .context("disarming the mesh-handshake timeout")?;
            Ok(q)
        })();
        match admitted {
            Ok(q) => {
                taken[q] = true;
                conns.push((q, stream));
                pending -= 1;
            }
            Err(e) => {
                crate::log!(
                    Warn,
                    "worker {worker}: rejected mesh dial-in from {peer_addr} ({e:#}); \
                     still waiting for {pending} peers"
                );
            }
        }
    }
    Ok(conns)
}

/// One dial-in's handshake on the leader side; `taken[w]` marks ranks
/// already admitted. Any failure rejects this connection only.
fn admit_worker(
    stream: &mut TcpStream,
    peer_addr: &str,
    workers: usize,
    taken: &[bool],
) -> Result<usize> {
    configure(stream)?;
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .context("arming the handshake timeout")?;
    let w = read_handshake(stream, &format!("dialer {peer_addr}"))? as usize;
    ensure!(
        w < workers,
        "dialer {peer_addr} claims worker rank {w}, but this star has {workers} workers"
    );
    ensure!(
        !taken[w],
        "two dialers claim worker rank {w} (duplicate --rank?)"
    );
    // The reply appends the leader's clock (unix micros) so the worker
    // can estimate its offset and rebase trace timestamps onto the
    // leader's timeline. One sample is coarse (no RTT halving), but the
    // spans it aligns are per-batch, not per-microsecond.
    stream
        .write_all(&handshake_bytes(workers as u16))
        .and_then(|_| stream.write_all(&crate::obs::now_us().to_le_bytes()))
        .and_then(|_| stream.flush())
        .with_context(|| format!("answering worker {w}'s handshake"))?;
    // Back to blocking reads: the reader thread owns this fd for the
    // whole run and must never see a spurious timeout.
    stream
        .set_read_timeout(None)
        .context("disarming the handshake timeout")?;
    Ok(w)
}

/// Worker side: dial the leader, handshake, and build the node.
///
/// The connect re-tries with exponential backoff until `timeout`
/// (bounded, never forever): `heta launch` starts every rank at once,
/// so workers routinely dial before the leader listens — and a
/// *respawned* rank dials while the old cluster is still tearing down.
/// The handshake reply reads run under [`HANDSHAKE_TIMEOUT`] so a
/// leader that accepts but never answers (wedged mid-teardown) errors
/// out instead of hanging the worker forever.
pub fn dial(
    leader_addr: &str,
    worker: usize,
    workers: usize,
    timeout: Duration,
) -> Result<TcpNode> {
    dial_with(leader_addr, worker, workers, timeout, HbCfg::default())
}

/// [`dial`] with explicit heartbeat timing.
pub fn dial_with(
    leader_addr: &str,
    worker: usize,
    workers: usize,
    timeout: Duration,
    hb: HbCfg,
) -> Result<TcpNode> {
    dial_impl(leader_addr, worker, workers, timeout, hb, false)
}

/// Worker side of a **mesh-enabled** star: dial the leader as usual,
/// then join the worker↔worker mesh the leader brokers (announce a
/// mesh listen address, read the table, dial every lower rank, accept
/// every higher one). Must be paired with
/// [`accept_workers_mesh_with`] on the leader — a plain leader never
/// brokers the table and this dial would time out waiting for it.
pub fn dial_mesh_with(
    leader_addr: &str,
    worker: usize,
    workers: usize,
    timeout: Duration,
    hb: HbCfg,
) -> Result<TcpNode> {
    dial_impl(leader_addr, worker, workers, timeout, hb, true)
}

fn dial_impl(
    leader_addr: &str,
    worker: usize,
    workers: usize,
    timeout: Duration,
    hb: HbCfg,
    mesh: bool,
) -> Result<TcpNode> {
    ensure!(
        worker < workers,
        "worker rank {worker} outside the {workers}-worker star"
    );
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(25);
    let mut stream = loop {
        match TcpStream::connect(leader_addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!(
                        "worker {worker} could not reach the leader at {leader_addr} \
                         within {timeout:?}: {e}"
                    );
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    };
    configure(&stream)?;
    stream
        .write_all(&handshake_bytes(worker as u16))
        .and_then(|_| stream.flush())
        .with_context(|| format!("worker {worker} sending its handshake"))?;
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .context("arming the handshake-reply timeout")?;
    let leader_rank = read_handshake(&mut stream, &format!("leader {leader_addr}"))? as usize;
    let mut ts = [0u8; 8];
    stream
        .read_exact(&mut ts)
        .with_context(|| format!("reading the leader clock from {leader_addr}"))?;
    // Back to blocking reads for the reader thread.
    stream
        .set_read_timeout(None)
        .context("disarming the handshake-reply timeout")?;
    let leader_now = u64::from_le_bytes(ts);
    crate::obs::set_clock_offset(leader_now as i64 - crate::obs::now_us() as i64);
    ensure!(
        leader_rank == workers,
        "leader at {leader_addr} runs a {leader_rank}-worker star, this rank expects \
         {workers} (mismatched --peers / num_partitions?)"
    );
    let mut conns = if mesh {
        mesh_join(&mut stream, worker, workers)?
    } else {
        Vec::new()
    };
    conns.push((workers, stream));
    build_node(worker, workers, conns, hb)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal test payload: the modeled system would ship the f32s.
    #[derive(Debug, PartialEq)]
    struct Msg {
        batch: u64,
        data: Vec<f32>,
    }

    impl Wire for Msg {
        fn wire_bytes(&self) -> u64 {
            (self.data.len() * 4) as u64
        }
    }

    impl WireCodec for Msg {
        fn encode(&self, w: &mut super::super::codec::ByteWriter) {
            w.u64(self.batch);
            w.f32s(&self.data);
        }
        fn decode(r: &mut super::super::codec::ByteReader<'_>) -> Result<Msg> {
            Ok(Msg {
                batch: r.u64()?,
                data: r.f32s()?,
            })
        }
    }

    fn loopback_star(workers: usize) -> (TcpNode, Vec<TcpNode>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let dialers: Vec<_> = (0..workers)
            .map(|w| {
                let addr = addr.clone();
                std::thread::spawn(move || dial(&addr, w, workers, DIAL_TIMEOUT).unwrap())
            })
            .collect();
        let leader = accept_workers(listener, workers).unwrap();
        let nodes = dialers.into_iter().map(|h| h.join().unwrap()).collect();
        (leader, nodes)
    }

    #[test]
    fn frames_route_by_lane_and_preserve_sender_fifo() {
        let (leader, workers) = loopback_star(2);
        let hub_up: TcpChannel<Msg> = leader.open_lane(LANE_DATA_UP).unwrap();
        let hub_bar: TcpChannel<()> = leader.open_lane(LANE_BARRIER_UP).unwrap();
        assert!(
            leader.open_lane::<Msg>(LANE_DATA_UP).is_err(),
            "a lane's receive queue exists once"
        );
        let handles: Vec<_> = workers
            .into_iter()
            .map(|node| {
                std::thread::spawn(move || {
                    let up: TcpChannel<Msg> = node.open_lane(LANE_DATA_UP).unwrap();
                    let bar: TcpChannel<()> = node.open_lane(LANE_BARRIER_UP).unwrap();
                    let me = node.rank() as u64;
                    for bi in 0..3u64 {
                        up.send(2, Msg { batch: bi, data: vec![me as f32; 4] }).unwrap();
                    }
                    bar.send(2, ()).unwrap();
                })
            })
            .collect();
        // 6 data frames, FIFO per sender; 2 barrier frames on their own
        // lane regardless of interleaving on the shared connections.
        let mut next = [0u64; 2];
        for _ in 0..6 {
            let e = hub_up.recv().unwrap();
            assert_eq!(e.payload.batch, next[e.from], "lane reordered");
            assert_eq!(e.payload.data, vec![e.from as f32; 4]);
            next[e.from] += 1;
        }
        for _ in 0..2 {
            hub_bar.recv().unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
        let t = hub_up.traffic();
        assert_eq!(t.frames_recv, 8);
        assert_eq!(t.modeled_recv, 6 * 16, "barrier frames are modeled-free");
        assert!(
            t.real_recv > t.modeled_recv,
            "real bytes carry headers + metadata: {t:?}"
        );
    }

    #[test]
    fn peer_death_surfaces_as_an_error_naming_the_peer() {
        let (leader, mut workers) = loopback_star(1);
        let hub_up: TcpChannel<Msg> = leader.open_lane(LANE_DATA_UP).unwrap();
        let w = workers.pop().unwrap();
        let wc: TcpChannel<Msg> = w.open_lane(LANE_DATA_UP).unwrap();
        wc.send(1, Msg { batch: 9, data: vec![] }).unwrap();
        drop(wc);
        drop(w); // shutdown: the reader sees EOF after the queued frame
        assert_eq!(hub_up.recv().unwrap().payload.batch, 9);
        let err = hub_up.recv().unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("rank 0"), "hangup must name the peer: {text}");
        // And sends to the dead peer fail too (possibly after a frame
        // sits in OS buffers — retry until the pipe breaks).
        let down: TcpChannel<Msg> = leader.open_lane(LANE_DATA_DOWN).unwrap();
        let mut saw_err = false;
        for _ in 0..200 {
            if down.send(0, Msg { batch: 0, data: vec![0.0; 256] }).is_err() {
                saw_err = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(saw_err, "writing to a dead peer must eventually error");
    }

    #[test]
    fn corrupt_frames_are_rejected_not_trusted() {
        let (leader, mut workers) = loopback_star(1);
        let hub_up: TcpChannel<Msg> = leader.open_lane(LANE_DATA_UP).unwrap();
        let w = workers.pop().unwrap();
        // Encode a valid frame, then truncate the payload: the decode
        // at the receiver must fail with context, not panic.
        let bar: TcpChannel<()> = w.open_lane(LANE_BARRIER_UP).unwrap();
        bar.send(1, ()).unwrap(); // prove the link first
        let hub_bar: TcpChannel<()> = leader.open_lane(LANE_BARRIER_UP).unwrap();
        hub_bar.recv().unwrap();
        // Hand-write a frame whose body is one byte short of its Msg.
        {
            let shared = &w.shared;
            let conn = shared.peers[1].as_ref().unwrap();
            let mut wr = lock(&conn.writer);
            let body = [LANE_DATA_UP, 1, 0, 0, 0, 0, 0, 0]; // u64 missing a byte
            wr.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
            wr.write_all(&body).unwrap();
            wr.flush().unwrap();
        }
        let err = hub_up.recv().unwrap_err();
        let text = format!("{err:#}");
        assert!(
            text.contains("decoding") && text.contains("truncated"),
            "corrupt frame must explain itself: {text}"
        );
    }

    #[test]
    fn stray_dialins_are_rejected_without_killing_the_cluster() {
        // A stray dial-in (bad magic: a port probe) must be dropped —
        // not deadlock the leader, not abort the run — and the star
        // must still form once the real worker arrives.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stray = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                // Best-effort: the leader may already have finished
                // accepting by the time the probe lands.
                if let Ok(mut s) = TcpStream::connect(addr) {
                    let _ = s.write_all(b"NOPE\x01\x00\x00\x00");
                    let _ = s.flush();
                }
            })
        };
        let real = {
            let addr = addr.clone();
            std::thread::spawn(move || dial(&addr, 0, 1, DIAL_TIMEOUT).unwrap())
        };
        let leader = accept_workers(listener, 1).expect("a stray probe must not kill accept");
        assert_eq!(leader.workers(), 1);
        stray.join().unwrap();
        let worker = real.join().unwrap();
        assert_eq!(worker.role(), Role::Worker(0));
    }

    #[test]
    fn handshake_rejects_wrong_worker_count() {
        // A worker expecting a different star size refuses the leader.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || accept_workers(listener, 2));
        let err = dial(&addr, 0, 3, DIAL_TIMEOUT).unwrap_err();
        assert!(
            format!("{err:#}").contains("star"),
            "mismatched star sizes must explain themselves: {err:#}"
        );
        drop(t); // leader side still waits for a second worker; abandon it
    }

    fn loopback_star_hb(workers: usize, hb: HbCfg) -> (TcpNode, Vec<TcpNode>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let dialers: Vec<_> = (0..workers)
            .map(|w| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    dial_with(&addr, w, workers, DIAL_TIMEOUT, hb).unwrap()
                })
            })
            .collect();
        let leader = accept_workers_with(listener, workers, hb).unwrap();
        let nodes = dialers.into_iter().map(|h| h.join().unwrap()).collect();
        (leader, nodes)
    }

    #[test]
    fn heartbeats_do_not_pollute_traffic_counters() {
        let hb = HbCfg {
            interval_ms: 10,
            timeout_ms: 5000,
        };
        let (leader, mut workers) = loopback_star_hb(1, hb);
        let hub_up: TcpChannel<Msg> = leader.open_lane(LANE_DATA_UP).unwrap();
        let w = workers.pop().unwrap();
        // Let a pile of heartbeats cross the wire: none of them may
        // show up in the counters, which tests (and EpochReport.wire)
        // treat as exact message counts.
        std::thread::sleep(Duration::from_millis(150));
        let t = leader.traffic();
        assert_eq!(t.frames_recv, 0, "heartbeats must not count as frames: {t:?}");
        assert_eq!(t.real_recv, 0, "heartbeats must not count as bytes: {t:?}");
        let wc: TcpChannel<Msg> = w.open_lane(LANE_DATA_UP).unwrap();
        wc.send(1, Msg { batch: 1, data: vec![2.0] }).unwrap();
        assert_eq!(hub_up.recv().unwrap().payload.batch, 1);
        assert_eq!(leader.traffic().frames_recv, 1);
    }

    #[test]
    fn a_stalled_worker_is_declared_dead_by_heartbeat_timeout() {
        let hb = HbCfg {
            interval_ms: 25,
            timeout_ms: 200,
        };
        let (leader, workers) = loopback_star_hb(1, hb);
        let hub_up: TcpChannel<Msg> = leader.open_lane(LANE_DATA_UP).unwrap();
        // The worker wedges: its process is alive (sockets open!) but it
        // stops proving liveness. Only the timeout can catch this.
        workers[0].pause_heartbeats();
        let err = hub_up.recv().unwrap_err();
        let text = format!("{err:#}");
        assert!(
            text.contains("heartbeat"),
            "a timeout kill must name its cause: {text}"
        );
        assert!(text.contains("rank 0"), "and the dead peer: {text}");
    }

    #[test]
    fn injected_frame_corruption_is_caught_by_total_decode() {
        let (leader, mut workers) = loopback_star(1);
        let hub_bar: TcpChannel<()> = leader.open_lane(LANE_BARRIER_UP).unwrap();
        let w = workers.pop().unwrap();
        let bar: TcpChannel<()> = w.open_lane(LANE_BARRIER_UP).unwrap();
        bar.send(1, ()).unwrap();
        hub_bar.recv().unwrap(); // clean frame first: the link works
        bar.sabotage(FaultKind::CorruptFrame);
        bar.send(1, ()).unwrap(); // the sender does not notice
        let err = hub_bar.recv().unwrap_err();
        let text = format!("{err:#}");
        assert!(
            text.contains("decoding"),
            "a corrupted body must fail decode, not desync: {text}"
        );
        // The corruption was one-shot: the next frame is clean.
        bar.send(1, ()).unwrap();
        hub_bar.recv().unwrap();
    }

    #[test]
    fn drop_conn_sabotage_hangs_up_every_lane() {
        let (leader, mut workers) = loopback_star(1);
        let hub_up: TcpChannel<Msg> = leader.open_lane(LANE_DATA_UP).unwrap();
        let w = workers.pop().unwrap();
        let wc: TcpChannel<Msg> = w.open_lane(LANE_DATA_UP).unwrap();
        wc.sabotage(FaultKind::DropConn);
        let err = hub_up.recv().unwrap_err();
        assert!(
            format!("{err:#}").contains("rank 0"),
            "the hangup must name the peer: {err:#}"
        );
    }

    fn loopback_mesh(workers: usize) -> (TcpNode, Vec<TcpNode>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let hb = HbCfg {
            interval_ms: 0,
            timeout_ms: 0,
        };
        let dialers: Vec<_> = (0..workers)
            .map(|w| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    dial_mesh_with(&addr, w, workers, DIAL_TIMEOUT, hb).unwrap()
                })
            })
            .collect();
        let leader = accept_workers_mesh_with(listener, workers, hb).unwrap();
        let nodes = dialers.into_iter().map(|h| h.join().unwrap()).collect();
        (leader, nodes)
    }

    #[test]
    fn mesh_workers_exchange_frames_rank_to_rank() {
        // Three workers so the mesh has both a dial edge (1→0, 2→0,
        // 2→1) and an accept edge per interior rank. Every worker ships
        // one frame to every other worker over the mesh lane and reads
        // the ones addressed to it.
        let (leader, workers) = loopback_mesh(3);
        let handles: Vec<_> = workers
            .into_iter()
            .map(|node| {
                std::thread::spawn(move || {
                    let mesh: TcpChannel<Msg> = node.open_lane(LANE_MESH_DATA).unwrap();
                    let me = node.rank();
                    for p in (0..3).filter(|&p| p != me) {
                        mesh.send(p, Msg { batch: me as u64, data: vec![me as f32] })
                            .unwrap();
                    }
                    let mut seen = [false; 3];
                    for _ in 0..2 {
                        let e = mesh.recv().unwrap();
                        assert_eq!(e.payload.batch, e.from as u64);
                        assert_eq!(e.payload.data, vec![e.from as f32]);
                        assert!(!seen[e.from], "duplicate mesh frame from {}", e.from);
                        seen[e.from] = true;
                    }
                    let t = mesh.traffic();
                    assert_eq!(t.mesh_sent, t.real_sent, "workers only sent on the mesh");
                    assert_eq!(t.mesh_recv, t.real_recv);
                    assert!(t.mesh_sent > 0 && t.mesh_recv > 0);
                    node
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // The leader never holds mesh sockets; its counters stay clean.
        let t = leader.traffic();
        assert_eq!(t.mesh_sent, 0);
        assert_eq!(t.mesh_recv, 0);
    }

    #[test]
    fn mesh_join_requires_a_mesh_leader() {
        // A mesh dial against a plain (non-brokering) leader must fail
        // with a real error, not wedge: the leader never sends the
        // table, and its next frame on the raw stream would desync. The
        // cheap observable half is table decode rejection.
        let err = parse_mesh_table(&[9, 0, 0, 0], 2).unwrap_err();
        assert!(
            format!("{err:#}").contains("mesh table"),
            "a wrong-size table must explain itself: {err:#}"
        );
        let err = parse_mesh_table(&[2, 0, 0], 2).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn broadcast_encoded_delivers_identical_frames_to_every_worker() {
        let (leader, workers) = loopback_star(2);
        let down: TcpChannel<Msg> = leader.open_lane(LANE_DATA_DOWN).unwrap();
        let payload = Msg {
            batch: 7,
            data: vec![1.5, -0.0, f32::MIN_POSITIVE],
        };
        down.broadcast_encoded(2, &payload).unwrap();
        let t = down.traffic();
        assert_eq!(t.frames_sent, 2, "one frame per worker, encoded once");
        assert_eq!(t.modeled_sent, 2 * payload.wire_bytes());
        assert_eq!(t.real_sent % 2, 0, "both copies are byte-identical");
        for node in &workers {
            let lane: TcpChannel<Msg> = node.open_lane(LANE_DATA_DOWN).unwrap();
            let e = lane.recv().unwrap();
            assert_eq!(e.from, 2);
            assert_eq!(e.payload, payload);
            assert_eq!(
                e.payload.data[1].to_bits(),
                (-0.0f32).to_bits(),
                "broadcast must preserve float bits exactly"
            );
        }
    }

    #[test]
    fn broadcast_encoded_one_shot_corruption_hits_exactly_one_copy() {
        let (leader, workers) = loopback_star(2);
        let down: TcpChannel<Msg> = leader.open_lane(LANE_DATA_DOWN).unwrap();
        let lanes: Vec<TcpChannel<Msg>> = workers
            .iter()
            .map(|n| n.open_lane(LANE_DATA_DOWN).unwrap())
            .collect();
        let payload = Msg { batch: 3, data: vec![2.0; 8] };
        leader.inject_corrupt_frame();
        down.broadcast_encoded(2, &payload).unwrap();
        let err = lanes[0].recv().unwrap_err();
        assert!(
            format!("{err:#}").contains("decoding"),
            "worker 0's copy was mangled: {err:#}"
        );
        assert_eq!(lanes[1].recv().unwrap().payload, payload, "worker 1's copy is clean");
        // The armament was one-shot: the next broadcast is clean.
        down.broadcast_encoded(2, &payload).unwrap();
        assert_eq!(lanes[1].recv().unwrap().payload, payload);
    }

    #[test]
    fn rank_mapping_and_roles() {
        let (leader, workers) = loopback_star(2);
        assert_eq!(leader.role(), Role::Leader);
        assert_eq!(leader.rank(), 2);
        assert_eq!(leader.workers(), 2);
        assert_eq!(workers[0].role(), Role::Worker(0));
        assert_eq!(workers[1].role(), Role::Worker(1));
        // Workers have no link to each other.
        let c: TcpChannel<Msg> = workers[0].open_lane(LANE_DATA_UP).unwrap();
        let err = c.send(1, Msg { batch: 0, data: vec![] }).unwrap_err();
        assert!(format!("{err}").contains("no socket"), "{err}");
    }
}
