//! Leveled logging with rank + batch prefixes.
//!
//! The `log!` macro replaces the ad-hoc `println!`/`eprintln!`
//! progress output that used to be scattered across `main.rs`,
//! `cluster/*`, and `net/*`. Every line is prefixed
//! `[heta r<rank> b<batch> <LEVEL>]` so the interleaved stderr of a
//! multi-process `heta launch` stays greppable per rank; `--log-level`
//! quiets CI. The rank comes from a process-global set once at
//! startup, the batch from the span recorder's thread-local tag.
//!
//! ```ignore
//! crate::log!(Info, "epoch {} done, loss {:.4}", ep, loss);
//! ```
//!
//! Format arguments are only evaluated when the level passes — the
//! macro checks [`log_enabled`] before calling `format!`.
//!
//! `--log-format json` switches every line to JSON-lines
//! (`{"ts_us":..,"rank":..,"batch":..,"level":"INFO","msg":".."}`) for
//! machine ingestion; the level gate is unchanged, so filtered-out
//! arguments stay unevaluated in both formats.

use std::sync::atomic::{AtomicI64, AtomicU8, Ordering};

use super::recorder;

/// Severity, most to least urgent. The default level is `Info`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl LogLevel {
    /// Parse a `--log-level` value (`error|warn|info|debug`).
    pub fn parse(s: &str) -> Option<LogLevel> {
        match s {
            "error" => Some(LogLevel::Error),
            "warn" => Some(LogLevel::Warn),
            "info" => Some(LogLevel::Info),
            "debug" => Some(LogLevel::Debug),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LogLevel::Error => "ERROR",
            LogLevel::Warn => "WARN",
            LogLevel::Info => "INFO",
            LogLevel::Debug => "DEBUG",
        }
    }
}

/// Output shape of one log line: the grep-friendly human prefix or
/// one JSON object per line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LogFormat {
    Human = 0,
    Json = 1,
}

impl LogFormat {
    /// Parse a `--log-format` value (`human|json`).
    pub fn parse(s: &str) -> Option<LogFormat> {
        match s {
            "human" => Some(LogFormat::Human),
            "json" => Some(LogFormat::Json),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(LogLevel::Info as u8);

static FORMAT: AtomicU8 = AtomicU8::new(LogFormat::Human as u8);

/// This process's rank for log prefixes; -1 (unset) omits the prefix.
static RANK: AtomicI64 = AtomicI64::new(-1);

pub fn set_log_level(level: LogLevel) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn set_log_format(format: LogFormat) {
    FORMAT.store(format as u8, Ordering::Relaxed);
}

fn log_format() -> LogFormat {
    if FORMAT.load(Ordering::Relaxed) == LogFormat::Json as u8 {
        LogFormat::Json
    } else {
        LogFormat::Human
    }
}

pub fn set_log_rank(rank: i64) {
    RANK.store(rank, Ordering::Relaxed);
}

/// Would a message at `level` print? The `log!` macro checks this
/// before formatting.
pub fn log_enabled(level: LogLevel) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one prefixed line to stderr. Called by the `log!` macro after
/// the level check; usable directly when the message is preformatted.
pub fn log_line(level: LogLevel, msg: String) {
    match log_format() {
        LogFormat::Human => eprintln!("{} {msg}", human_prefix(level)),
        LogFormat::Json => eprintln!("{}", json_line(level, &msg)),
    }
}

fn human_prefix(level: LogLevel) -> String {
    let mut prefix = String::from("[heta");
    let rank = RANK.load(Ordering::Relaxed);
    if rank >= 0 {
        prefix.push_str(&format!(" r{rank}"));
    }
    if let Some(batch) = recorder::current_batch() {
        prefix.push_str(&format!(" b{batch}"));
    }
    prefix.push(' ');
    prefix.push_str(level.name());
    prefix.push(']');
    prefix
}

/// One JSON-lines record: `ts_us` on the recorder clock so log lines
/// and trace spans share a timebase; `rank`/`batch` are null when
/// unset, matching the human prefix's omission.
fn json_line(level: LogLevel, msg: &str) -> String {
    use crate::util::json::Json;
    let rank = RANK.load(Ordering::Relaxed);
    Json::from_pairs(vec![
        ("ts_us", Json::num(recorder::now_us() as f64)),
        ("rank", if rank >= 0 { Json::num(rank as f64) } else { Json::Null }),
        (
            "batch",
            recorder::current_batch().map_or(Json::Null, |b| Json::num(b as f64)),
        ),
        ("level", Json::str(level.name())),
        ("msg", Json::str(msg)),
    ])
    .to_string()
}

/// Leveled log with rank+batch prefix: `log!(Info, "fmt {}", args)`.
/// Levels are the [`LogLevel`](crate::obs::LogLevel) variant names.
/// Arguments are not evaluated when the level is filtered out.
#[macro_export]
macro_rules! log {
    ($lvl:ident, $($arg:tt)*) => {{
        if $crate::obs::log_enabled($crate::obs::LogLevel::$lvl) {
            $crate::obs::log_line($crate::obs::LogLevel::$lvl, format!($($arg)*));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_names() {
        assert_eq!(LogLevel::parse("error"), Some(LogLevel::Error));
        assert_eq!(LogLevel::parse("warn"), Some(LogLevel::Warn));
        assert_eq!(LogLevel::parse("info"), Some(LogLevel::Info));
        assert_eq!(LogLevel::parse("debug"), Some(LogLevel::Debug));
        assert_eq!(LogLevel::parse("verbose"), None);
        assert_eq!(LogLevel::Warn.name(), "WARN");
    }

    #[test]
    fn log_format_parse_and_json_lines() {
        assert_eq!(LogFormat::parse("human"), Some(LogFormat::Human));
        assert_eq!(LogFormat::parse("json"), Some(LogFormat::Json));
        assert_eq!(LogFormat::parse("yaml"), None);
        // The JSON record parses and escapes hostile messages.
        let line = json_line(LogLevel::Warn, "quote \" backslash \\ newline \n done");
        let doc = crate::util::json::parse(&line).expect("json log line must parse");
        assert_eq!(doc.get("level").as_str(), Some("WARN"));
        assert_eq!(
            doc.get("msg").as_str(),
            Some("quote \" backslash \\ newline \n done")
        );
        assert!(doc.get("ts_us").as_f64().is_some());
        assert!(!line.contains('\n'), "JSON-lines records must be single lines");
        // Unset rank/batch serialize as null, like the human prefix
        // omits them.
        assert!(matches!(doc.get("batch"), crate::util::json::Json::Null));
    }

    #[test]
    fn level_ordering_filters() {
        // Note: LEVEL is process-global; restore the default so other
        // tests (running in this binary) keep their Info default.
        set_log_level(LogLevel::Warn);
        assert!(log_enabled(LogLevel::Error));
        assert!(log_enabled(LogLevel::Warn));
        assert!(!log_enabled(LogLevel::Info));
        assert!(!log_enabled(LogLevel::Debug));
        set_log_level(LogLevel::Debug);
        assert!(log_enabled(LogLevel::Debug));
        set_log_level(LogLevel::Info);
    }
}
