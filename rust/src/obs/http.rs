//! Live telemetry plane (PR 10): a hand-rolled, dependency-free
//! HTTP/1.1 server every rank can arm with `--metrics-addr host:port`.
//!
//! Three endpoints:
//!
//! - `/metrics` — Prometheus text exposition (version 0.0.4) rendered
//!   from the cumulative [`metrics::peek`] view, so a scrape never
//!   steals epoch deltas from `EpochReport.obs`. Dotted metric keys
//!   are sanitized to exposition names (`wire.lane0.tx_bytes` →
//!   `wire_lane0_tx_bytes`); every sample carries a `rank` label.
//! - `/healthz` — JSON liveness: rank, role, epoch/batch progress, and
//!   per-peer heartbeat lag read from the same `LANE_HB` last-heard
//!   stamps the leader's monitor thread watches. Returns 503 once any
//!   registered peer has been declared dead, so a plain HTTP check
//!   sees a degraded cluster. The leader's page shows every worker —
//!   cluster-wide liveness from one scrape.
//! - `/buildinfo` — name/version/codec, for fleet inventory.
//!
//! Arming the plane flips [`recorder::set_enabled`] on, so the
//! `wire.lane*` / `cache.*` / `hb.*` / `serve.*` families tick even
//! without `--trace`. Like everything in `obs/`, the plane is
//! observationally free: with no `--metrics-addr` there is no listener
//! thread, no clock read, and no registered peer state — the hooks
//! below all gate on a relaxed [`armed`] load and return immediately.
//! Losses are byte-identical either way (pinned in
//! `tests/test_obs_trace.rs`).
//!
//! The server itself reuses the `net/` socket idioms (blocking
//! accept loop, `BufReader` framing, explicit shutdown) but speaks
//! HTTP/1.1 with `Connection: close` — one request per connection is
//! plenty for a scraper.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::metrics::{self, LiveView, BUCKET_BOUNDS};
use super::recorder;

// ---- health state ----

/// One watched peer connection: the same atomics the TCP reader /
/// heartbeat monitor stamp, shared here so `/healthz` reads liveness
/// without its own socket traffic.
#[derive(Clone)]
pub struct PeerHealth {
    pub peer: usize,
    /// `recorder::now_us` stamp of the last complete frame from this
    /// peer (any lane — data proves liveness as well as heartbeats).
    pub last_heard_us: Arc<AtomicU64>,
    /// Set once by the heartbeat monitor when it declares the peer
    /// dead and shuts the connection.
    pub timed_out: Arc<AtomicBool>,
}

/// What `/healthz` serves: identity, progress, and the watched peers.
/// An instance type (like [`metrics::MetricsRegistry`]) so the
/// dead-peer fixture test drives its own; the process-global one is
/// fed through the `health_*` free functions.
pub struct HealthState {
    rank: AtomicI64,
    role: Mutex<String>,
    epoch: AtomicI64,
    batch: AtomicI64,
    peers: Mutex<Vec<PeerHealth>>,
}

impl HealthState {
    pub const fn new() -> HealthState {
        HealthState {
            rank: AtomicI64::new(-1),
            role: Mutex::new(String::new()),
            epoch: AtomicI64::new(-1),
            batch: AtomicI64::new(-1),
            peers: Mutex::new(Vec::new()),
        }
    }

    pub fn set_identity(&self, rank: i64, role: &str) {
        self.rank.store(rank, Ordering::Relaxed);
        *lock(&self.role) = role.to_string();
    }

    pub fn set_epoch(&self, epoch: i64) {
        self.epoch.store(epoch, Ordering::Relaxed);
    }

    pub fn set_batch(&self, batch: i64) {
        self.batch.store(batch, Ordering::Relaxed);
    }

    /// Register (or re-register, after a reconnect) a peer's liveness
    /// stamps. Keyed by peer rank — the newest connection wins.
    pub fn register_peer(&self, p: PeerHealth) {
        let mut peers = lock(&self.peers);
        if let Some(slot) = peers.iter_mut().find(|q| q.peer == p.peer) {
            *slot = p;
        } else {
            peers.push(p);
            peers.sort_by_key(|q| q.peer);
        }
    }

    /// The `/healthz` page at clock reading `now_us`, plus whether the
    /// cluster view is fully alive (false ⇒ HTTP 503). `now_us` is a
    /// parameter so the fixture test is deterministic.
    pub fn healthz_json(&self, now_us: u64) -> (Json, bool) {
        let opt = |v: i64| if v < 0 { Json::Null } else { Json::num(v as f64) };
        let mut all_alive = true;
        let peers: Vec<Json> = lock(&self.peers)
            .iter()
            .map(|p| {
                let lag_us = now_us.saturating_sub(p.last_heard_us.load(Ordering::SeqCst));
                let dead = p.timed_out.load(Ordering::SeqCst);
                all_alive &= !dead;
                Json::from_pairs(vec![
                    ("rank", Json::num(p.peer as f64)),
                    ("last_heard_ms", Json::num(lag_us as f64 / 1000.0)),
                    ("alive", Json::Bool(!dead)),
                ])
            })
            .collect();
        let body = Json::from_pairs(vec![
            ("status", Json::str(if all_alive { "ok" } else { "degraded" })),
            ("rank", opt(self.rank.load(Ordering::Relaxed))),
            ("role", {
                let r = lock(&self.role);
                if r.is_empty() { Json::Null } else { Json::str(r.as_str()) }
            }),
            ("epoch", opt(self.epoch.load(Ordering::Relaxed))),
            ("batch", opt(self.batch.load(Ordering::Relaxed))),
            ("peers", Json::Arr(peers)),
        ]);
        (body, all_alive)
    }
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

static HEALTH: HealthState = HealthState::new();

/// Flipped once by [`start`]. Every health hook below gates on this
/// relaxed load, so an unarmed run does no work past one atomic read.
static ARMED: AtomicBool = AtomicBool::new(false);

/// Is the telemetry plane armed (`--metrics-addr` given)?
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Record this process's identity on the global health page.
pub fn health_set_identity(rank: i64, role: &str) {
    if armed() {
        HEALTH.set_identity(rank, role);
    }
}

/// Epoch-progress hook, called from the coordinator's epoch loop.
pub fn health_set_epoch(epoch: i64) {
    if armed() {
        HEALTH.set_epoch(epoch);
    }
}

/// Batch-progress hook, called from [`recorder::set_batch`] — one
/// relaxed load when unarmed, one extra relaxed store per batch when
/// armed. Never reads a clock.
pub fn health_note_batch(batch: i64) {
    if armed() {
        HEALTH.set_batch(batch);
    }
}

/// Share a connection's liveness stamps with `/healthz` (called from
/// `net/tcp.rs` as each star connection is built).
pub fn health_register_peer(peer: usize, last_heard_us: Arc<AtomicU64>, timed_out: Arc<AtomicBool>) {
    if armed() {
        HEALTH.register_peer(PeerHealth {
            peer,
            last_heard_us,
            timed_out,
        });
    }
}

// ---- the exposition renderer ----

/// Sanitize a dotted metric key into a Prometheus metric name:
/// `[a-zA-Z0-9_:]` pass through, everything else becomes `_`, and a
/// leading digit gets a `_` prefix.
pub fn sanitize_name(key: &str) -> String {
    let mut out = String::with_capacity(key.len() + 1);
    for (i, c) in key.chars().enumerate() {
        let ok = c.is_ascii_alphanumeric() || c == '_' || c == ':';
        if i == 0 && c.is_ascii_digit() {
            out.push('_');
        }
        out.push(if ok { c } else { '_' });
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Escape a label value per the text exposition format: backslash,
/// double-quote, and newline.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Escape a HELP line: backslash and newline (quotes are legal there).
pub fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render a [`LiveView`] as Prometheus text exposition. Histograms
/// expand into cumulative `_bucket{le=...}` series over
/// [`BUCKET_BOUNDS`] plus `+Inf`, `_sum`, and `_count`; `le` counts
/// are monotone non-decreasing and the `+Inf` bucket equals `_count`
/// by construction (pinned by the round-trip test).
pub fn render_prometheus(view: &LiveView, rank: u64) -> String {
    let mut out = String::new();
    let label = format!("rank=\"{}\"", escape_label(&rank.to_string()));
    for (key, v) in &view.counters {
        let name = sanitize_name(key);
        out.push_str(&format!(
            "# HELP {name} heta counter `{}` (cumulative since process start)\n",
            escape_help(key)
        ));
        out.push_str(&format!("# TYPE {name} counter\n"));
        out.push_str(&format!("{name}{{{label}}} {v}\n"));
    }
    for (key, v) in &view.gauges {
        let name = sanitize_name(key);
        out.push_str(&format!("# HELP {name} heta gauge `{}`\n", escape_help(key)));
        out.push_str(&format!("# TYPE {name} gauge\n"));
        out.push_str(&format!("{name}{{{label}}} {v}\n"));
    }
    for (key, summary, buckets) in &view.hists {
        let name = sanitize_name(key);
        out.push_str(&format!("# HELP {name} heta histogram `{}`\n", escape_help(key)));
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cum = 0u64;
        for (i, bound) in BUCKET_BOUNDS.iter().enumerate() {
            cum += buckets.get(i).copied().unwrap_or(0);
            out.push_str(&format!("{name}_bucket{{{label},le=\"{bound}\"}} {cum}\n"));
        }
        out.push_str(&format!(
            "{name}_bucket{{{label},le=\"+Inf\"}} {}\n",
            summary.count
        ));
        out.push_str(&format!("{name}_sum{{{label}}} {}\n", summary.sum));
        out.push_str(&format!("{name}_count{{{label}}} {}\n", summary.count));
    }
    out
}

// ---- the server ----

/// A running telemetry listener. The accept thread is detached — it
/// lives until process exit, like the `net/` reader threads; there is
/// nothing to join because a scraper can connect at any time.
pub struct TelemetryServer {
    /// The bound address (resolves `:0` for tests).
    pub addr: SocketAddr,
}

/// Bind `addr`, arm the health hooks, flip the recorder on (so the
/// metric families tick without `--trace`), and spawn the accept
/// loop. Call once, early, before the transport dials — peers
/// register their liveness stamps only while armed.
pub fn start(addr: &str, rank: i64, role: &str) -> Result<TelemetryServer> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding the telemetry listener on {addr}"))?;
    let local = listener.local_addr().context("reading the bound telemetry address")?;
    ARMED.store(true, Ordering::SeqCst);
    HEALTH.set_identity(rank, role);
    recorder::set_enabled(true);
    std::thread::Builder::new()
        .name("heta-telemetry".to_string())
        .spawn(move || {
            for conn in listener.incoming() {
                if let Ok(stream) = conn {
                    let _ = handle_conn(stream);
                }
            }
        })
        .context("spawning the telemetry accept thread")?;
    crate::log!(
        Info,
        "telemetry: serving /metrics /healthz /buildinfo on http://{local}"
    );
    Ok(TelemetryServer { addr: local })
}

/// One request per connection: read the request line, drain headers,
/// route, respond, close. Malformed input gets a 400; anything that
/// is not `GET`/`HEAD` gets a 405.
fn handle_conn(stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    // Drain headers (bounded — a scraper sends a handful).
    let mut hdr = String::new();
    for _ in 0..128 {
        hdr.clear();
        let n = reader.read_line(&mut hdr)?;
        if n == 0 || hdr == "\r\n" || hdr == "\n" {
            break;
        }
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, ctype, body) = route(method, path);
    let head_only = method == "HEAD";
    respond(stream, status, ctype, &body, head_only)
}

/// Route one request to `(status line, content type, body)`.
fn route(method: &str, path: &str) -> (&'static str, &'static str, String) {
    if method != "GET" && method != "HEAD" {
        return (
            "405 Method Not Allowed",
            "text/plain; charset=utf-8",
            "method not allowed\n".to_string(),
        );
    }
    // Ignore any query string — scrapers add ?format= etc.
    let path = path.split('?').next().unwrap_or(path);
    match path {
        "/metrics" => {
            let rank = HEALTH.rank.load(Ordering::Relaxed).max(0) as u64;
            (
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                render_prometheus(&metrics::peek(), rank),
            )
        }
        "/healthz" => {
            let (body, alive) = HEALTH.healthz_json(recorder::now_us());
            (
                if alive { "200 OK" } else { "503 Service Unavailable" },
                "application/json",
                format!("{body}\n"),
            )
        }
        "/buildinfo" => {
            let body = Json::from_pairs(vec![
                ("name", Json::str("heta")),
                ("version", Json::str(env!("CARGO_PKG_VERSION"))),
                (
                    "codec_version",
                    Json::num(crate::net::codec::CODEC_VERSION as f64),
                ),
                ("debug_assertions", Json::Bool(cfg!(debug_assertions))),
            ]);
            ("200 OK", "application/json", format!("{body}\n"))
        }
        "/" => (
            "200 OK",
            "text/plain; charset=utf-8",
            "heta telemetry: /metrics /healthz /buildinfo\n".to_string(),
        ),
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found\n".to_string(),
        ),
    }
}

fn respond(
    mut stream: TcpStream,
    status: &str,
    ctype: &str,
    body: &str,
    head_only: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    if !head_only {
        stream.write_all(body.as_bytes())?;
    }
    stream.flush()?;
    let _ = stream.shutdown(Shutdown::Both);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::MetricsRegistry;
    use std::collections::BTreeMap;

    // A tiny exposition parser for the round-trip tests: returns
    // sample name → (labels → value), plus the set of TYPE lines.
    fn parse_exposition(
        text: &str,
    ) -> (BTreeMap<String, Vec<(BTreeMap<String, String>, f64)>>, BTreeMap<String, String>) {
        let mut samples: BTreeMap<String, Vec<(BTreeMap<String, String>, f64)>> = BTreeMap::new();
        let mut types = BTreeMap::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let name = it.next().expect("TYPE name").to_string();
                let ty = it.next().expect("TYPE kind").to_string();
                types.insert(name, ty);
                continue;
            }
            if line.starts_with('#') || line.trim().is_empty() {
                continue;
            }
            let (head, value) = line.rsplit_once(' ').expect("sample line");
            let value: f64 = value.parse().expect("sample value");
            let (name, labels) = match head.split_once('{') {
                Some((n, rest)) => {
                    let rest = rest.strip_suffix('}').expect("closing brace");
                    let mut map = BTreeMap::new();
                    // Labels in our renderer never contain escaped
                    // commas inside values other than via backslash;
                    // split naively then unescape.
                    for pair in rest.split(',') {
                        let (k, v) = pair.split_once('=').expect("label pair");
                        let v = v.trim_matches('"').replace("\\\"", "\"").replace("\\\\", "\\");
                        map.insert(k.to_string(), v);
                    }
                    (n.to_string(), map)
                }
                None => (head.to_string(), BTreeMap::new()),
            };
            samples.entry(name).or_default().push((labels, value));
        }
        (samples, types)
    }

    #[test]
    fn name_sanitization_and_escaping() {
        assert_eq!(sanitize_name("wire.lane0.tx_bytes"), "wire_lane0_tx_bytes");
        assert_eq!(sanitize_name("cache.paper-v2.hits"), "cache_paper_v2_hits");
        assert_eq!(sanitize_name("0weird"), "_0weird");
        assert_eq!(sanitize_name(""), "_");
        // Property over a grid of hostile inputs: sanitized names are
        // always legal, escapes always single-line and reversible.
        let hostiles = [
            "a b", "ab\"c", "x\\y", "new\nline", "ünïcode", "1.2.3", "::", "-",
        ];
        for h in hostiles {
            let n = sanitize_name(h);
            assert!(!n.is_empty());
            assert!(
                n.chars().enumerate().all(|(i, c)| {
                    (c.is_ascii_alphanumeric() && (i > 0 || !c.is_ascii_digit()))
                        || c == '_'
                        || c == ':'
                        || (i > 0 && c.is_ascii_digit())
                }),
                "sanitize({h:?}) = {n:?} has an illegal char"
            );
            let e = escape_label(h);
            assert!(!e.contains('\n'), "escape_label({h:?}) leaked a newline");
            let back = e.replace("\\n", "\n").replace("\\\"", "\"").replace("\\\\", "\\");
            // Unescaping in reverse order differs only for inputs
            // containing literal \n / \" sequences, which our keys
            // never do; for this grid the round trip must hold.
            assert_eq!(back, h, "escape_label not reversible for {h:?}");
            assert!(!escape_help(h).contains('\n'));
        }
    }

    #[test]
    fn exposition_round_trips_through_parser() {
        let reg = MetricsRegistry::new();
        reg.counter_add("wire.lane0.tx_bytes", 123);
        reg.counter_add("wire.lane1.rx_bytes", 7);
        reg.gauge_set("hb.rank1.last_heard_ms", 41.5);
        for v in [0.05, 0.3, 3.0, 40.0, 1e9] {
            reg.hist_observe("serve.latency_ms", v);
        }
        let text = render_prometheus(&reg.peek(), 3);
        let (samples, types) = parse_exposition(&text);
        assert_eq!(types.get("wire_lane0_tx_bytes").map(String::as_str), Some("counter"));
        assert_eq!(types.get("hb_rank1_last_heard_ms").map(String::as_str), Some("gauge"));
        assert_eq!(types.get("serve_latency_ms").map(String::as_str), Some("histogram"));
        let one = |name: &str| {
            let s = &samples[name];
            assert_eq!(s.len(), 1, "{name} should have one sample");
            assert_eq!(s[0].0.get("rank").map(String::as_str), Some("3"));
            s[0].1
        };
        assert_eq!(one("wire_lane0_tx_bytes"), 123.0);
        assert_eq!(one("wire_lane1_rx_bytes"), 7.0);
        assert_eq!(one("hb_rank1_last_heard_ms"), 41.5);
        assert_eq!(one("serve_latency_ms_count"), 5.0);
        // Bucket cumulativity: le-ordered counts are monotone and the
        // +Inf bucket equals _count.
        let buckets = &samples["serve_latency_ms_bucket"];
        assert_eq!(buckets.len(), BUCKET_BOUNDS.len() + 1);
        let mut prev = 0.0;
        for (labels, v) in buckets {
            assert!(*v >= prev, "le buckets must be monotone");
            prev = *v;
            assert!(labels.contains_key("le"));
        }
        let inf = buckets.last().expect("+Inf bucket");
        assert_eq!(inf.0.get("le").map(String::as_str), Some("+Inf"));
        assert_eq!(inf.1, 5.0, "+Inf bucket must equal the total count");
        // 1e9 is above every bound: the last finite bucket excludes it.
        assert_eq!(buckets[BUCKET_BOUNDS.len() - 1].1, 4.0);
    }

    #[test]
    fn healthz_reports_dead_peer_as_degraded() {
        let h = HealthState::new();
        h.set_identity(2, "leader");
        h.set_epoch(4);
        h.set_batch(17);
        let alive_stamp = Arc::new(AtomicU64::new(1_000_000));
        let dead_stamp = Arc::new(AtomicU64::new(200_000));
        let dead_flag = Arc::new(AtomicBool::new(true));
        h.register_peer(PeerHealth {
            peer: 0,
            last_heard_us: Arc::clone(&alive_stamp),
            timed_out: Arc::new(AtomicBool::new(false)),
        });
        h.register_peer(PeerHealth {
            peer: 1,
            last_heard_us: Arc::clone(&dead_stamp),
            timed_out: Arc::clone(&dead_flag),
        });
        let (body, all_alive) = h.healthz_json(1_500_000);
        assert!(!all_alive, "a timed-out peer must degrade the page");
        assert_eq!(body.get("status").as_str(), Some("degraded"));
        assert_eq!(body.get("rank").as_u64(), Some(2));
        assert_eq!(body.get("role").as_str(), Some("leader"));
        assert_eq!(body.get("epoch").as_u64(), Some(4));
        assert_eq!(body.get("batch").as_u64(), Some(17));
        let peers = body.get("peers").as_arr().expect("peers array");
        assert_eq!(peers.len(), 2);
        assert_eq!(peers[0].get("alive").as_bool(), Some(true));
        assert_eq!(peers[0].get("last_heard_ms").as_f64(), Some(0.5));
        assert_eq!(peers[1].get("alive").as_bool(), Some(false));
        assert_eq!(peers[1].get("last_heard_ms").as_f64(), Some(1.3));
        // Revive: the flag clears (fresh connection re-registers) and
        // the page goes green again.
        h.register_peer(PeerHealth {
            peer: 1,
            last_heard_us: dead_stamp,
            timed_out: Arc::new(AtomicBool::new(false)),
        });
        let (body, all_alive) = h.healthz_json(1_500_000);
        assert!(all_alive);
        assert_eq!(body.get("status").as_str(), Some("ok"));
        // The JSON body is parseable by our own parser.
        let text = format!("{body}");
        assert!(crate::util::json::parse(&text).is_ok());
    }

    #[test]
    fn server_serves_all_endpoints_over_real_http() {
        use std::io::Read;
        // Drive the real listener + routing on a loopback socket. The
        // request is hand-written HTTP/1.1, the response read raw.
        let server = start("127.0.0.1:0", 0, "leader").expect("bind telemetry");
        let fetch = |path: &str| -> (String, String) {
            let mut s = TcpStream::connect(server.addr).expect("connect");
            let req = format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n");
            s.write_all(req.as_bytes()).unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            let (head, body) = buf.split_once("\r\n\r\n").expect("header/body split");
            (head.to_string(), body.to_string())
        };
        let (head, body) = fetch("/buildinfo");
        assert!(head.starts_with("HTTP/1.1 200"), "buildinfo: {head}");
        let info = crate::util::json::parse(&body).expect("buildinfo json");
        assert_eq!(info.get("name").as_str(), Some("heta"));
        let (head, _) = fetch("/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "metrics: {head}");
        assert!(head.contains("text/plain"));
        let (head, body) = fetch("/healthz");
        assert!(head.starts_with("HTTP/1.1 200"), "healthz: {head}");
        assert!(crate::util::json::parse(&body).is_ok());
        let (head, _) = fetch("/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "404: {head}");
    }
}
