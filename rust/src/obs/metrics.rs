//! Counters, gauges, and histogram summaries — the numeric half of the
//! flight recorder.
//!
//! A [`MetricsRegistry`] is an instance: unit tests build their own so
//! they never race the process-global one. Engine code ticks the
//! module-level free functions ([`counter_add`], [`gauge_max`],
//! [`gauge_set`], [`hist_observe`]), which gate on
//! [`recorder::enabled`] (zero work when tracing is off) and delegate
//! to the process-global registry; [`snapshot_and_reset`] drains that
//! registry into the epoch's [`MetricsSnapshot`].
//!
//! Every tick lands in two places: the *epoch* maps, drained by
//! [`snapshot_and_reset`] into `EpochReport.obs`, and the *cumulative*
//! maps, read non-destructively by [`MetricsRegistry::peek`] for the
//! live `/metrics` endpoint (`obs::http`). A scrape therefore never
//! steals deltas from the epoch report. Cumulative histograms
//! additionally bin samples into the fixed [`BUCKET_BOUNDS`] ladder so
//! the exposition can emit Prometheus `le` buckets without touching
//! [`HistSummary`]'s wire shape (the codec stays at version 4).
//!
//! Naming convention: dotted paths, lowest-cardinality first —
//! `wire.lane0.tx_bytes`, `cache.<node-type>.hits`, `staleness.open`,
//! `grad.version_lag`. Keys are sorted (BTreeMap) so snapshots are
//! deterministic and diffable.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::Result;

use crate::net::codec::{ByteReader, ByteWriter, WireCodec};

use super::recorder;

/// Streaming summary of a distribution — count/sum/min/max is enough
/// to read mean and spread per epoch without storing samples.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for HistSummary {
    fn default() -> HistSummary {
        HistSummary {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl HistSummary {
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &HistSummary) {
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }
}

impl WireCodec for HistSummary {
    fn encode(&self, w: &mut ByteWriter) {
        w.u64(self.count);
        w.f64(self.sum);
        w.f64(self.min);
        w.f64(self.max);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<HistSummary> {
        Ok(HistSummary {
            count: r.u64()?,
            sum: r.f64()?,
            min: r.f64()?,
            max: r.f64()?,
        })
    }
}

/// One epoch's worth of metrics from one rank (or, after merging on
/// the leader, from all of them). Entries stay sorted by key.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, HistSummary)>,
}

impl MetricsSnapshot {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Counter value by key (0 when absent) — test/report convenience.
    pub fn counter(&self, key: &str) -> u64 {
        self.counters
            .iter()
            .find(|(k, _)| k == key)
            .map(|&(_, v)| v)
            .unwrap_or(0)
    }

    /// Fold `other` in: counters add, gauges keep the max, histograms
    /// merge componentwise. Keys stay sorted.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        fn fold<V: Clone>(
            into: &mut Vec<(String, V)>,
            from: &[(String, V)],
            combine: impl Fn(&mut V, &V),
        ) {
            for (k, v) in from {
                match into.binary_search_by(|(ik, _)| ik.as_str().cmp(k)) {
                    Ok(i) => combine(&mut into[i].1, v),
                    Err(i) => into.insert(i, (k.clone(), v.clone())),
                }
            }
        }
        fold(&mut self.counters, &other.counters, |a, b| *a += *b);
        fold(&mut self.gauges, &other.gauges, |a, b| *a = a.max(*b));
        fold(&mut self.hists, &other.hists, |a, b| a.merge(b));
    }
}

impl WireCodec for MetricsSnapshot {
    fn encode(&self, w: &mut ByteWriter) {
        w.u32(self.counters.len() as u32);
        for (k, v) in &self.counters {
            w.str(k);
            w.u64(*v);
        }
        w.u32(self.gauges.len() as u32);
        for (k, v) in &self.gauges {
            w.str(k);
            w.f64(*v);
        }
        w.u32(self.hists.len() as u32);
        for (k, h) in &self.hists {
            w.str(k);
            h.encode(w);
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<MetricsSnapshot> {
        // Element minima: a counter entry is ≥ 4 (name len) + 8 bytes,
        // a gauge likewise, a hist entry ≥ 4 + 32.
        let n = r.seq_len(12)?;
        let mut counters = Vec::with_capacity(n);
        for _ in 0..n {
            let k = r.str()?;
            counters.push((k, r.u64()?));
        }
        let n = r.seq_len(12)?;
        let mut gauges = Vec::with_capacity(n);
        for _ in 0..n {
            let k = r.str()?;
            gauges.push((k, r.f64()?));
        }
        let n = r.seq_len(36)?;
        let mut hists = Vec::with_capacity(n);
        for _ in 0..n {
            let k = r.str()?;
            hists.push((k, HistSummary::decode(r)?));
        }
        Ok(MetricsSnapshot {
            counters,
            gauges,
            hists,
        })
    }
}

/// Fixed upper bounds for the live exposition's histogram buckets, in
/// the native unit of the observed series (ours are milliseconds). An
/// exponential 0.1 ms → 2.5 s ladder; samples above the last bound
/// only land in the implicit `+Inf` bucket (= total count).
pub const BUCKET_BOUNDS: [f64; 14] = [
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0,
];

/// Cumulative histogram cell: the summary plus per-bound sample counts
/// (non-cumulative — the exposition renderer accumulates them into
/// monotone `le` buckets).
#[derive(Debug, Clone)]
struct CumHist {
    summary: HistSummary,
    buckets: [u64; BUCKET_BOUNDS.len()],
}

impl Default for CumHist {
    fn default() -> CumHist {
        CumHist {
            summary: HistSummary::default(),
            buckets: [0; BUCKET_BOUNDS.len()],
        }
    }
}

impl CumHist {
    fn observe(&mut self, v: f64) {
        self.summary.observe(v);
        if let Some(i) = BUCKET_BOUNDS.iter().position(|&b| v <= b) {
            self.buckets[i] += 1;
        }
    }
}

/// Non-draining view of the cumulative maps — what a live `/metrics`
/// scrape renders. `hists` carries each key's summary plus its
/// per-bound (non-cumulative) bucket counts aligned with
/// [`BUCKET_BOUNDS`]; the `+Inf` overflow is `summary.count` minus the
/// bucket sum.
#[derive(Debug, Clone, Default)]
pub struct LiveView {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, HistSummary, Vec<u64>)>,
}

impl LiveView {
    /// Counter value by key (0 when absent) — test convenience.
    pub fn counter(&self, key: &str) -> u64 {
        self.counters
            .iter()
            .find(|(k, _)| k == key)
            .map(|&(_, v)| v)
            .unwrap_or(0)
    }
}

/// A set of live metric cells. Instance methods never gate on the
/// recorder switch — gating belongs to the free functions below, so
/// tests drive their own registries unconditionally. Each tick is
/// double-written: once into the epoch maps ([`snapshot_and_reset`]
/// drains those) and once into the cumulative maps ([`peek`] reads
/// them without draining).
///
/// [`snapshot_and_reset`]: MetricsRegistry::snapshot_and_reset
/// [`peek`]: MetricsRegistry::peek
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    hists: Mutex<BTreeMap<String, HistSummary>>,
    cum_counters: Mutex<BTreeMap<String, u64>>,
    cum_gauges: Mutex<BTreeMap<String, f64>>,
    cum_hists: Mutex<BTreeMap<String, CumHist>>,
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl MetricsRegistry {
    pub const fn new() -> MetricsRegistry {
        MetricsRegistry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            hists: Mutex::new(BTreeMap::new()),
            cum_counters: Mutex::new(BTreeMap::new()),
            cum_gauges: Mutex::new(BTreeMap::new()),
            cum_hists: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn counter_add(&self, key: &str, delta: u64) {
        if delta == 0 {
            return;
        }
        fn bump(map: &Mutex<BTreeMap<String, u64>>, key: &str, delta: u64) {
            let mut c = lock(map);
            match c.get_mut(key) {
                Some(v) => *v += delta,
                None => {
                    c.insert(key.to_string(), delta);
                }
            }
        }
        bump(&self.counters, key, delta);
        bump(&self.cum_counters, key, delta);
    }

    pub fn gauge_max(&self, key: &str, value: f64) {
        fn raise(map: &Mutex<BTreeMap<String, f64>>, key: &str, value: f64) {
            let mut g = lock(map);
            match g.get_mut(key) {
                Some(v) => *v = v.max(value),
                None => {
                    g.insert(key.to_string(), value);
                }
            }
        }
        raise(&self.gauges, key, value);
        raise(&self.cum_gauges, key, value);
    }

    /// Last-value gauge write (vs [`gauge_max`]'s high-water
    /// semantics) — for signals that move both ways, like heartbeat
    /// lag or instantaneous QPS.
    ///
    /// [`gauge_max`]: MetricsRegistry::gauge_max
    pub fn gauge_set(&self, key: &str, value: f64) {
        lock(&self.gauges).insert(key.to_string(), value);
        lock(&self.cum_gauges).insert(key.to_string(), value);
    }

    pub fn hist_observe(&self, key: &str, value: f64) {
        lock(&self.hists)
            .entry(key.to_string())
            .or_default()
            .observe(value);
        lock(&self.cum_hists)
            .entry(key.to_string())
            .or_default()
            .observe(value);
    }

    /// Drain everything recorded since the last snapshot. BTreeMap
    /// iteration keeps the snapshot's vectors sorted by key. The
    /// cumulative maps are untouched — a concurrent [`peek`] never
    /// changes what this returns.
    ///
    /// [`peek`]: MetricsRegistry::peek
    pub fn snapshot_and_reset(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: std::mem::take(&mut *lock(&self.counters)).into_iter().collect(),
            gauges: std::mem::take(&mut *lock(&self.gauges)).into_iter().collect(),
            hists: std::mem::take(&mut *lock(&self.hists)).into_iter().collect(),
        }
    }

    /// Non-draining snapshot of the cumulative maps — the live
    /// `/metrics` read path. Sorted by key like every snapshot.
    pub fn peek(&self) -> LiveView {
        LiveView {
            counters: lock(&self.cum_counters)
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
            gauges: lock(&self.cum_gauges)
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect(),
            hists: lock(&self.cum_hists)
                .iter()
                .map(|(k, h)| (k.clone(), h.summary.clone(), h.buckets.to_vec()))
                .collect(),
        }
    }
}

impl Default for MetricsRegistry {
    fn default() -> MetricsRegistry {
        MetricsRegistry::new()
    }
}

static GLOBAL: MetricsRegistry = MetricsRegistry::new();

/// Add to a process-global counter (no-op unless tracing is enabled).
pub fn counter_add(key: &str, delta: u64) {
    if recorder::enabled() {
        GLOBAL.counter_add(key, delta);
    }
}

/// Raise a process-global high-water gauge (no-op unless enabled).
pub fn gauge_max(key: &str, value: f64) {
    if recorder::enabled() {
        GLOBAL.gauge_max(key, value);
    }
}

/// Overwrite a process-global last-value gauge (no-op unless enabled).
pub fn gauge_set(key: &str, value: f64) {
    if recorder::enabled() {
        GLOBAL.gauge_set(key, value);
    }
}

/// Record one sample into a process-global histogram (no-op unless
/// enabled).
pub fn hist_observe(key: &str, value: f64) {
    if recorder::enabled() {
        GLOBAL.hist_observe(key, value);
    }
}

/// Drain the process-global registry for this epoch's blob.
pub fn snapshot_and_reset() -> MetricsSnapshot {
    GLOBAL.snapshot_and_reset()
}

/// Non-draining view of the process-global cumulative maps — what the
/// `/metrics` endpoint renders. Not gated: the only caller is the
/// telemetry server, which exists only when `--metrics-addr` armed it.
pub fn peek() -> LiveView {
    GLOBAL.peek()
}

/// Publish a serving run's headline latency/throughput gauges
/// (`serve.p50_ms` / `serve.p99_ms` / `serve.qps`) — the same numbers
/// `BENCH_serve.json` reports, so the trace and the bench agree by
/// construction. No-op unless tracing is enabled.
pub fn record_serve_summary(p50_ms: f64, p99_ms: f64, qps: f64) {
    gauge_max("serve.p50_ms", p50_ms);
    gauge_max("serve.p99_ms", p99_ms);
    gauge_max("serve.qps", qps);
}

/// Publish per-node-type cache traffic for one epoch: `before`/`after`
/// are `(hits, misses)` ledger readings per node type, `names` the node
/// type names, `penalty_ratios` each type's miss-penalty ratio. Ticks
/// `cache.<type>.hits` / `cache.<type>.misses` counters with the deltas
/// and a `cache.<type>.penalty_ratio` gauge — the same ledger
/// `BENCH_gather.json` reads, so the trace and the bench agree on
/// fetch traffic by construction.
pub fn record_cache_counters(
    names: &[String],
    before: &[(u64, u64)],
    after: &[(u64, u64)],
    penalty_ratios: &[f64],
) {
    if !recorder::enabled() {
        return;
    }
    for (ty, name) in names.iter().enumerate() {
        let (h0, m0) = before.get(ty).copied().unwrap_or((0, 0));
        let (h1, m1) = after.get(ty).copied().unwrap_or((0, 0));
        counter_add(&format!("cache.{name}.hits"), h1.saturating_sub(h0));
        counter_add(&format!("cache.{name}.misses"), m1.saturating_sub(m0));
        if let Some(&p) = penalty_ratios.get(ty) {
            gauge_max(&format!("cache.{name}.penalty_ratio"), p);
        }
    }
}

/// Epoch-start ledger reading for [`record_cache_obs`]: `(hits,
/// misses)` per node type. `None` when the recorder is off or the
/// context runs cacheless — the matching epoch-end call then no-ops.
pub fn cache_obs_base(cache: Option<&crate::cache::FeatureCache>) -> Option<Vec<(u64, u64)>> {
    if !recorder::enabled() {
        return None;
    }
    cache.map(|c| c.types.iter().map(|t| (t.hits, t.misses)).collect())
}

/// Epoch-end half: diff the cache's ledger against the `base` taken at
/// epoch start and publish per-node-type hit/miss/penalty counters via
/// [`record_cache_counters`]. Node-type names come from the graph
/// schema (ledger index == node-type id).
pub fn record_cache_obs(
    g: &crate::hetgraph::HetGraph,
    cache: Option<&crate::cache::FeatureCache>,
    base: Option<&[(u64, u64)]>,
) {
    if let (Some(cache), Some(base)) = (cache, base) {
        let names: Vec<String> = g.schema.node_types.iter().map(|t| t.name.clone()).collect();
        let after: Vec<(u64, u64)> = cache.types.iter().map(|t| (t.hits, t.misses)).collect();
        let ratios: Vec<f64> = cache.types.iter().map(|t| t.penalty_ratio).collect();
        record_cache_counters(&names, base, &after, &ratios);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::codec::{decode_message, encode_message};

    #[test]
    fn registry_records_and_resets() {
        let reg = MetricsRegistry::new();
        reg.counter_add("b.count", 2);
        reg.counter_add("a.count", 1);
        reg.counter_add("b.count", 3);
        reg.counter_add("zero", 0); // ignored: no key materialized
        reg.gauge_max("depth", 2.0);
        reg.gauge_max("depth", 1.0); // max keeps 2.0
        reg.hist_observe("lag", 1.0);
        reg.hist_observe("lag", 3.0);
        let snap = reg.snapshot_and_reset();
        assert_eq!(
            snap.counters,
            vec![("a.count".to_string(), 1), ("b.count".to_string(), 5)],
            "counters must sum and stay sorted"
        );
        assert_eq!(snap.gauges, vec![("depth".to_string(), 2.0)]);
        assert_eq!(snap.hists.len(), 1);
        let h = &snap.hists[0].1;
        assert_eq!((h.count, h.sum, h.min, h.max), (2, 4.0, 1.0, 3.0));
        assert_eq!(h.mean(), 2.0);
        assert!(reg.snapshot_and_reset().is_empty(), "snapshot must reset");
    }

    #[test]
    fn snapshot_merge_semantics() {
        let a = MetricsSnapshot {
            counters: vec![("x".into(), 2), ("y".into(), 1)],
            gauges: vec![("g".into(), 1.0)],
            hists: vec![("h".into(), {
                let mut h = HistSummary::default();
                h.observe(5.0);
                h
            })],
        };
        let b = MetricsSnapshot {
            counters: vec![("w".into(), 7), ("x".into(), 3)],
            gauges: vec![("g".into(), 4.0), ("q".into(), -1.0)],
            hists: vec![("h".into(), {
                let mut h = HistSummary::default();
                h.observe(1.0);
                h.observe(2.0);
                h
            })],
        };
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(
            m.counters,
            vec![("w".to_string(), 7), ("x".to_string(), 5), ("y".to_string(), 1)],
            "counters add by key, insertion keeps sort order"
        );
        assert_eq!(m.gauges, vec![("g".to_string(), 4.0), ("q".to_string(), -1.0)]);
        let h = &m.hists[0].1;
        assert_eq!((h.count, h.sum, h.min, h.max), (3, 8.0, 1.0, 5.0));
        assert_eq!(m.counter("x"), 5);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn snapshot_codec_round_trips_and_rejects_truncation() {
        let snap = MetricsSnapshot {
            counters: vec![("wire.lane0.tx_bytes".into(), u64::MAX), ("z".into(), 0)],
            gauges: vec![("staleness.open".into(), 2.5)],
            hists: vec![("grad.version_lag".into(), {
                let mut h = HistSummary::default();
                h.observe(0.0);
                h.observe(3.0);
                h
            })],
        };
        let bytes = encode_message(&snap);
        let back: MetricsSnapshot = decode_message(&bytes).unwrap();
        assert_eq!(back, snap);
        for cut in 0..bytes.len() {
            assert!(
                decode_message::<MetricsSnapshot>(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must be rejected",
                bytes.len()
            );
        }
        // Empty snapshot round-trips too (the tracing-off wire shape).
        let empty = MetricsSnapshot::default();
        let bytes = encode_message(&empty);
        assert_eq!(decode_message::<MetricsSnapshot>(&bytes).unwrap(), empty);
    }

    #[test]
    fn empty_hist_mean_is_nan_and_merge_identity() {
        let mut h = HistSummary::default();
        assert!(h.mean().is_nan());
        let mut sample = HistSummary::default();
        sample.observe(2.0);
        h.merge(&sample);
        assert_eq!((h.count, h.min, h.max), (1, 2.0, 2.0));
    }

    #[test]
    fn peek_is_cumulative_and_never_steals_epoch_deltas() {
        let reg = MetricsRegistry::new();
        reg.counter_add("wire.lane0.tx_bytes", 10);
        reg.gauge_max("staleness.open", 2.0);
        reg.hist_observe("serve.latency_ms", 1.5);
        // A live scrape between ticks must not perturb the epoch drain.
        let live = reg.peek();
        assert_eq!(live.counter("wire.lane0.tx_bytes"), 10);
        let epoch = reg.snapshot_and_reset();
        assert_eq!(epoch.counter("wire.lane0.tx_bytes"), 10, "peek stole the delta");
        assert_eq!(epoch.gauges, vec![("staleness.open".to_string(), 2.0)]);
        // Epoch maps drained; cumulative keeps accumulating across epochs.
        reg.counter_add("wire.lane0.tx_bytes", 5);
        assert_eq!(reg.peek().counter("wire.lane0.tx_bytes"), 15);
        assert_eq!(reg.snapshot_and_reset().counter("wire.lane0.tx_bytes"), 5);
        // And peek itself is non-draining.
        assert_eq!(reg.peek().counter("wire.lane0.tx_bytes"), 15);
    }

    #[test]
    fn gauge_set_is_last_value_both_views() {
        let reg = MetricsRegistry::new();
        reg.gauge_set("hb.rank1.last_heard_ms", 100.0);
        reg.gauge_set("hb.rank1.last_heard_ms", 3.0); // falls — set, not max
        assert_eq!(reg.peek().gauges, vec![("hb.rank1.last_heard_ms".to_string(), 3.0)]);
        assert_eq!(
            reg.snapshot_and_reset().gauges,
            vec![("hb.rank1.last_heard_ms".to_string(), 3.0)]
        );
    }

    #[test]
    fn cumulative_hist_buckets_bin_samples() {
        let reg = MetricsRegistry::new();
        // One sample per interesting region: below the first bound,
        // exactly on a bound (le is inclusive), and above the last.
        reg.hist_observe("serve.latency_ms", 0.05);
        reg.hist_observe("serve.latency_ms", 1.0);
        reg.hist_observe("serve.latency_ms", 1e6);
        let live = reg.peek();
        let (key, summary, buckets) = &live.hists[0];
        assert_eq!(key, "serve.latency_ms");
        assert_eq!(summary.count, 3);
        assert_eq!(buckets.len(), BUCKET_BOUNDS.len());
        assert_eq!(buckets[0], 1, "0.05 lands in le=0.1");
        let i = BUCKET_BOUNDS.iter().position(|&b| b == 1.0).unwrap();
        assert_eq!(buckets[i], 1, "1.0 lands in le=1.0 inclusively");
        let binned: u64 = buckets.iter().sum();
        assert_eq!(summary.count - binned, 1, "1e6 only in the implicit +Inf");
    }

    #[test]
    fn cache_counters_tick_deltas() {
        let reg = &GLOBAL; // free fns gate on enabled(); drive instance directly
        let names = vec!["paper".to_string(), "author".to_string()];
        let before = vec![(10, 2), (0, 0)];
        let after = vec![(15, 2), (4, 6)];
        // Simulate what record_cache_counters does, without the global
        // gate, against a local registry.
        let local = MetricsRegistry::new();
        for (ty, name) in names.iter().enumerate() {
            let (h0, m0) = before[ty];
            let (h1, m1) = after[ty];
            local.counter_add(&format!("cache.{name}.hits"), h1 - h0);
            local.counter_add(&format!("cache.{name}.misses"), m1 - m0);
        }
        let snap = local.snapshot_and_reset();
        assert_eq!(snap.counter("cache.paper.hits"), 5);
        assert_eq!(snap.counter("cache.paper.misses"), 0, "zero delta → no key");
        assert_eq!(snap.counter("cache.author.hits"), 4);
        assert_eq!(snap.counter("cache.author.misses"), 6);
        let _ = reg; // silence unused in case gating changes
    }
}
