//! Export the flight recorder's epoch report as Chrome trace-event
//! JSON (openable in Perfetto / `chrome://tracing`).
//!
//! One process per rank (`pid` = rank), one track per recorded thread
//! (`tid` = track index), one complete event (`"ph":"X"`) per span.
//! Stall spans carry a `cname` so wire-wait and barrier-wait stand out
//! from compute at a glance; every event's `args` carry the batch and
//! lane for drill-down. The metrics snapshot rides along under a
//! top-level `"metrics"` key (ignored by trace viewers, read by the CI
//! validator and humans).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::recorder::{
    kind_name, TraceTrack, KIND_BARRIER_WAIT, KIND_WIRE_WAIT, LANE_NONE, NO_BATCH_U64,
};
use super::{HistSummary, MetricsSnapshot, ObsReport};

/// Chrome's stock palette names for stall coloring: data lanes pop,
/// barrier lanes and barriers go grey.
fn stall_cname(kind: u8, lane: u8) -> Option<&'static str> {
    match (kind, lane) {
        (KIND_BARRIER_WAIT, _) => Some("grey"),
        (KIND_WIRE_WAIT, 0) => Some("thread_state_iowait"),
        (KIND_WIRE_WAIT, 1) => Some("thread_state_running"),
        (KIND_WIRE_WAIT, _) => Some("grey"),
        _ => None,
    }
}

fn track_events(track: &TraceTrack, tid: usize, t_min: u64, out: &mut Vec<Json>) {
    // Two metadata events name the process (rank) and thread rows.
    out.push(Json::from_pairs(vec![
        ("ph", Json::str("M")),
        ("name", Json::str("process_name")),
        ("pid", Json::Num(track.rank as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::from_pairs(vec![("name", Json::str(format!("rank {}", track.rank)))])),
    ]));
    out.push(Json::from_pairs(vec![
        ("ph", Json::str("M")),
        ("name", Json::str("thread_name")),
        ("pid", Json::Num(track.rank as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::from_pairs(vec![("name", Json::str(track.thread.clone()))])),
    ]));
    for e in &track.events {
        let name = track
            .names
            .get(e.name_idx as usize)
            .map(String::as_str)
            .unwrap_or("?");
        let mut pairs = vec![
            ("ph", Json::str("X")),
            ("name", Json::str(name)),
            ("cat", Json::str(kind_name(e.kind))),
            ("pid", Json::Num(track.rank as f64)),
            ("tid", Json::Num(tid as f64)),
            ("ts", Json::Num(e.t0_us.saturating_sub(t_min) as f64)),
            ("dur", Json::Num(e.t1_us.saturating_sub(e.t0_us) as f64)),
            (
                "args",
                Json::from_pairs(vec![
                    (
                        "batch",
                        if e.batch == NO_BATCH_U64 { Json::Null } else { Json::Num(e.batch as f64) },
                    ),
                    (
                        "lane",
                        if e.lane == LANE_NONE { Json::Null } else { Json::Num(e.lane as f64) },
                    ),
                ]),
            ),
        ];
        if let Some(c) = stall_cname(e.kind, e.lane) {
            pairs.push(("cname", Json::str(c)));
        }
        out.push(Json::from_pairs(pairs));
    }
}

fn hist_json(h: &HistSummary) -> Json {
    Json::from_pairs(vec![
        ("count", Json::Num(h.count as f64)),
        ("sum", Json::Num(h.sum)),
        ("min", Json::Num(h.min)),
        ("max", Json::Num(h.max)),
        ("mean", Json::Num(h.mean())),
    ])
}

fn metrics_json(m: &MetricsSnapshot) -> Json {
    // Dynamic keys: build the maps directly (from_pairs is for
    // statically known keys).
    let counters: BTreeMap<String, Json> = m
        .counters
        .iter()
        .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
        .collect();
    let gauges: BTreeMap<String, Json> =
        m.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
    let hists: BTreeMap<String, Json> =
        m.hists.iter().map(|(k, h)| (k.clone(), hist_json(h))).collect();
    Json::from_pairs(vec![
        ("counters", Json::Obj(counters)),
        ("gauges", Json::Obj(gauges)),
        ("histograms", Json::Obj(hists)),
    ])
}

/// Render an [`ObsReport`] as a Chrome trace-event JSON document.
/// Timestamps rebase to the earliest span so traces start at t=0.
pub fn chrome_trace_json(report: &ObsReport) -> Json {
    let t_min = report
        .tracks
        .iter()
        .flat_map(|t| t.events.iter().map(|e| e.t0_us))
        .min()
        .unwrap_or(0);
    let mut events = Vec::new();
    for (tid, track) in report.tracks.iter().enumerate() {
        track_events(track, tid, t_min, &mut events);
    }
    Json::from_pairs(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        ("metrics", metrics_json(&report.metrics)),
    ])
}

/// Write the Chrome trace for `report` to `path`.
pub fn export_chrome(report: &ObsReport, path: &str) -> Result<()> {
    let json = chrome_trace_json(report);
    std::fs::write(path, json.to_string()).with_context(|| format!("writing trace to {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::{ObsEvent, KIND_COMPUTE};
    use crate::util::json::parse;

    fn sample_report() -> ObsReport {
        let mut metrics = MetricsSnapshot::default();
        metrics.counters.push(("wire.lane0.tx_bytes".into(), 128));
        let mut h = HistSummary::default();
        h.observe(2.0);
        metrics.hists.push(("grad.version_lag".into(), h));
        ObsReport {
            tracks: vec![
                TraceTrack {
                    rank: 0,
                    thread: "worker".into(),
                    dropped: 0,
                    names: vec!["fwd".into(), "gather.recv".into()],
                    events: vec![
                        ObsEvent {
                            batch: 0,
                            kind: KIND_COMPUTE,
                            lane: LANE_NONE,
                            name_idx: 0,
                            t0_us: 1_000,
                            t1_us: 1_500,
                        },
                        ObsEvent {
                            batch: NO_BATCH_U64,
                            kind: KIND_BARRIER_WAIT,
                            lane: 2,
                            name_idx: 1,
                            t0_us: 1_500,
                            t1_us: 1_900,
                        },
                    ],
                },
                TraceTrack {
                    rank: 1,
                    thread: "worker".into(),
                    dropped: 0,
                    names: vec!["recv".into()],
                    events: vec![ObsEvent {
                        batch: 3,
                        kind: KIND_WIRE_WAIT,
                        lane: 1,
                        name_idx: 0,
                        t0_us: 1_200,
                        t1_us: 1_300,
                    }],
                },
            ],
            metrics,
        }
    }

    #[test]
    fn export_parses_and_covers_ranks() {
        let report = sample_report();
        let text = chrome_trace_json(&report).to_string();
        let json = parse(&text).expect("exported trace must be valid JSON");
        let events = json.get("traceEvents").as_arr().unwrap();
        // 2 metadata per track + 3 spans.
        assert_eq!(events.len(), 2 * 2 + 3);
        let pids: std::collections::BTreeSet<u64> =
            events.iter().filter_map(|e| e.get("pid").as_u64()).collect();
        assert_eq!(pids.into_iter().collect::<Vec<_>>(), vec![0, 1]);
        // Earliest span rebases to ts=0.
        let spans: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").as_str() == Some("X")).collect();
        assert_eq!(spans.iter().filter_map(|e| e.get("ts").as_u64()).min(), Some(0));
        // Stall spans carry cname + cat; compute does not.
        let stall = spans.iter().find(|e| e.get("cat").as_str() == Some("barrier-wait")).unwrap();
        assert_eq!(stall.get("cname").as_str(), Some("grey"));
        assert!(stall.get("args").get("batch").as_u64().is_none(), "NO_BATCH exports as null");
        let compute = spans.iter().find(|e| e.get("cat").as_str() == Some("compute")).unwrap();
        assert_eq!(compute.get("cname").as_str(), None);
        assert_eq!(compute.get("dur").as_u64(), Some(500));
        assert_eq!(compute.get("args").get("batch").as_u64(), Some(0));
        // Metrics ride along.
        assert_eq!(
            json.get("metrics").get("counters").get("wire.lane0.tx_bytes").as_u64(),
            Some(128)
        );
        assert_eq!(
            json.get("metrics").get("histograms").get("grad.version_lag").get("count").as_u64(),
            Some(1)
        );
    }

    #[test]
    fn empty_report_is_still_valid_json() {
        let text = chrome_trace_json(&ObsReport::default()).to_string();
        let json = parse(&text).expect("empty trace must parse");
        assert_eq!(json.get("traceEvents").as_arr().map(<[Json]>::len), Some(0));
    }
}
