//! Offline trace analytics (`heta analyze`) and the perf-regression
//! gate (`heta bench-gate`).
//!
//! `analyze` consumes the Chrome-trace JSON written by `--trace`
//! ([`super::export::chrome_trace_json`]): complete events (`ph:"X"`)
//! with `pid` = rank, `cat` = span kind (compute / marshal / wire-wait
//! / barrier-wait), and `args.batch` / `args.lane` for drill-down. It
//! produces:
//!
//! - per-rank stall-attribution rollups (µs by kind),
//! - per-rank/per-lane wire-wait rollups,
//! - the top-N longest stalls with their batch indices,
//! - a critical-path extraction: per-batch wall windows and which
//!   rank's span ends each window (the batch's critical rank),
//! - and, with `--baseline`, a diff that prints regressions.
//!
//! `bench-gate` compares two `BENCH_*.json` documents leaf-by-leaf:
//! every numeric leaf is flattened to a dotted path, matched against
//! the baseline, and judged directionally — latency/bytes/miss-like
//! keys must not grow past `1 + tolerance`, qps/throughput-like keys
//! must not shrink below `1 - tolerance`. Keys with no known
//! direction are reported but never fail the gate. The self-test
//! below injects a 2x slowdown and asserts the gate trips.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Span-kind order used by every rollup (mirrors `recorder::KIND_*`).
pub const KINDS: [&str; 4] = ["compute", "marshal", "wire-wait", "barrier-wait"];

/// Stall kinds — the subset of [`KINDS`] that means "waiting".
const STALL_KINDS: [&str; 2] = ["wire-wait", "barrier-wait"];

/// One complete event pulled out of `traceEvents`.
#[derive(Debug, Clone)]
struct Ev {
    rank: u64,
    cat: String,
    name: String,
    ts_us: u64,
    dur_us: u64,
    batch: Option<u64>,
    lane: Option<u64>,
}

/// Per-rank rollup: µs attributed to each kind, in [`KINDS`] order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RankRollup {
    pub rank: u64,
    pub by_kind_us: [u64; 4],
    pub events: usize,
}

impl RankRollup {
    pub fn total_us(&self) -> u64 {
        self.by_kind_us.iter().sum()
    }

    pub fn stall_us(&self) -> u64 {
        self.by_kind_us[2] + self.by_kind_us[3]
    }
}

/// Wire-wait µs for one (rank, lane) pair.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LaneRollup {
    pub rank: u64,
    pub lane: u64,
    pub wait_us: u64,
    pub events: usize,
}

/// One stall span, for the top-N table.
#[derive(Debug, Clone, PartialEq)]
pub struct Stall {
    pub rank: u64,
    pub kind: String,
    pub name: String,
    pub batch: Option<u64>,
    pub lane: Option<u64>,
    pub ts_us: u64,
    pub dur_us: u64,
}

/// One batch's wall window across every rank, and the rank whose span
/// closes it — the batch's critical rank (the cluster cannot advance
/// past the batch before that span ends).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchWindow {
    pub batch: u64,
    pub t0_us: u64,
    pub t1_us: u64,
    pub crit_rank: u64,
    pub crit_kind: String,
    pub crit_name: String,
}

impl BatchWindow {
    pub fn span_us(&self) -> u64 {
        self.t1_us.saturating_sub(self.t0_us)
    }
}

/// Everything `heta analyze` extracts from one trace.
#[derive(Debug, Clone, Default)]
pub struct TraceSummary {
    pub ranks: Vec<RankRollup>,
    pub lanes: Vec<LaneRollup>,
    /// Longest stalls, descending by duration (capped at [`TOP_N`]).
    pub stalls: Vec<Stall>,
    /// Per-batch windows in batch order — the critical path.
    pub windows: Vec<BatchWindow>,
    /// Batches whose critical span belongs to each rank.
    pub crit_batches_by_rank: BTreeMap<u64, usize>,
    pub events: usize,
}

pub const TOP_N: usize = 10;

fn parse_events(doc: &Json) -> Result<Vec<Ev>> {
    let events = doc
        .get("traceEvents")
        .as_arr()
        .context("not a Chrome trace: missing traceEvents array")?;
    let mut out = Vec::new();
    for e in events {
        if e.get("ph").as_str() != Some("X") {
            continue; // metadata rows
        }
        out.push(Ev {
            rank: e.get("pid").as_u64().unwrap_or(0),
            cat: e.get("cat").as_str().unwrap_or("unknown").to_string(),
            name: e.get("name").as_str().unwrap_or("?").to_string(),
            ts_us: e.get("ts").as_u64().unwrap_or(0),
            dur_us: e.get("dur").as_u64().unwrap_or(0),
            batch: e.get("args").get("batch").as_u64(),
            lane: e.get("args").get("lane").as_u64(),
        });
    }
    Ok(out)
}

fn kind_idx(cat: &str) -> Option<usize> {
    KINDS.iter().position(|&k| k == cat)
}

/// Analyze one parsed trace document.
pub fn analyze(doc: &Json) -> Result<TraceSummary> {
    let evs = parse_events(doc)?;
    let mut ranks: BTreeMap<u64, RankRollup> = BTreeMap::new();
    let mut lanes: BTreeMap<(u64, u64), LaneRollup> = BTreeMap::new();
    let mut stalls: Vec<Stall> = Vec::new();
    let mut windows: BTreeMap<u64, (u64, u64, u64, String, String)> = BTreeMap::new();
    for e in &evs {
        let r = ranks.entry(e.rank).or_insert_with(|| RankRollup {
            rank: e.rank,
            ..Default::default()
        });
        r.events += 1;
        if let Some(k) = kind_idx(&e.cat) {
            r.by_kind_us[k] += e.dur_us;
        }
        if e.cat == "wire-wait" {
            if let Some(lane) = e.lane {
                let l = lanes.entry((e.rank, lane)).or_insert_with(|| LaneRollup {
                    rank: e.rank,
                    lane,
                    ..Default::default()
                });
                l.wait_us += e.dur_us;
                l.events += 1;
            }
        }
        if STALL_KINDS.contains(&e.cat.as_str()) {
            stalls.push(Stall {
                rank: e.rank,
                kind: e.cat.clone(),
                name: e.name.clone(),
                batch: e.batch,
                lane: e.lane,
                ts_us: e.ts_us,
                dur_us: e.dur_us,
            });
        }
        if let Some(b) = e.batch {
            let end = e.ts_us + e.dur_us;
            let w = windows
                .entry(b)
                .or_insert((e.ts_us, end, e.rank, e.cat.clone(), e.name.clone()));
            w.0 = w.0.min(e.ts_us);
            if end >= w.1 {
                w.1 = end;
                w.2 = e.rank;
                w.3 = e.cat.clone();
                w.4 = e.name.clone();
            }
        }
    }
    stalls.sort_by(|a, b| b.dur_us.cmp(&a.dur_us).then(a.ts_us.cmp(&b.ts_us)));
    stalls.truncate(TOP_N);
    let windows: Vec<BatchWindow> = windows
        .into_iter()
        .map(|(batch, (t0, t1, rank, kind, name))| BatchWindow {
            batch,
            t0_us: t0,
            t1_us: t1,
            crit_rank: rank,
            crit_kind: kind,
            crit_name: name,
        })
        .collect();
    let mut crit_batches_by_rank: BTreeMap<u64, usize> = BTreeMap::new();
    for w in &windows {
        *crit_batches_by_rank.entry(w.crit_rank).or_insert(0) += 1;
    }
    Ok(TraceSummary {
        ranks: ranks.into_values().collect(),
        lanes: lanes.into_values().collect(),
        stalls,
        windows,
        crit_batches_by_rank,
        events: evs.len(),
    })
}

/// Load + parse + analyze a trace file.
pub fn analyze_file(path: &str) -> Result<TraceSummary> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let doc = crate::util::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing trace {path}: {e:?}"))?;
    analyze(&doc).with_context(|| format!("analyzing trace {path}"))
}

fn ms(us: u64) -> f64 {
    us as f64 / 1000.0
}

/// Render the human-readable report.
pub fn render_text(s: &TraceSummary) -> String {
    let mut out = String::new();
    out.push_str(&format!("== trace: {} events, {} ranks ==\n", s.events, s.ranks.len()));
    out.push_str("per-rank stall attribution (ms):\n");
    out.push_str("  rank   compute   marshal wire-wait  barr-wait  stall%\n");
    for r in &s.ranks {
        let total = r.total_us().max(1);
        out.push_str(&format!(
            "  {:>4} {:>9.2} {:>9.2} {:>9.2} {:>10.2} {:>6.1}\n",
            r.rank,
            ms(r.by_kind_us[0]),
            ms(r.by_kind_us[1]),
            ms(r.by_kind_us[2]),
            ms(r.by_kind_us[3]),
            100.0 * r.stall_us() as f64 / total as f64,
        ));
    }
    if !s.lanes.is_empty() {
        out.push_str("per-lane wire-wait (ms):\n");
        for l in &s.lanes {
            out.push_str(&format!(
                "  rank {} lane {}: {:.2} ms over {} waits\n",
                l.rank,
                l.lane,
                ms(l.wait_us),
                l.events
            ));
        }
    }
    if !s.stalls.is_empty() {
        out.push_str(&format!("top {} stalls:\n", s.stalls.len()));
        for st in &s.stalls {
            let batch = st.batch.map_or("-".to_string(), |b| b.to_string());
            let lane = st.lane.map_or("-".to_string(), |l| l.to_string());
            out.push_str(&format!(
                "  {:>9.3} ms  rank {} batch {:>4} lane {:>2}  {} ({})\n",
                ms(st.dur_us),
                st.rank,
                batch,
                lane,
                st.name,
                st.kind
            ));
        }
    }
    if !s.windows.is_empty() {
        let mut longest: Vec<&BatchWindow> = s.windows.iter().collect();
        longest.sort_by(|a, b| b.span_us().cmp(&a.span_us()));
        out.push_str("critical path (longest batch windows):\n");
        for w in longest.iter().take(5) {
            out.push_str(&format!(
                "  batch {:>4}: {:>9.3} ms, closed by rank {} {} ({})\n",
                w.batch,
                ms(w.span_us()),
                w.crit_rank,
                w.crit_name,
                w.crit_kind
            ));
        }
        out.push_str("critical batches by rank:");
        for (rank, n) in &s.crit_batches_by_rank {
            out.push_str(&format!(" r{rank}={n}"));
        }
        out.push('\n');
    }
    out
}

/// Render the `--json` report.
pub fn render_json(s: &TraceSummary) -> Json {
    let ranks: Vec<Json> = s
        .ranks
        .iter()
        .map(|r| {
            let kinds: BTreeMap<String, Json> = KINDS
                .iter()
                .zip(r.by_kind_us.iter())
                .map(|(k, &us)| (k.to_string(), Json::num(ms(us))))
                .collect();
            Json::from_pairs(vec![
                ("rank", Json::num(r.rank as f64)),
                ("events", Json::num(r.events as f64)),
                ("ms_by_kind", Json::Obj(kinds)),
                ("stall_ms", Json::num(ms(r.stall_us()))),
            ])
        })
        .collect();
    let lanes: Vec<Json> = s
        .lanes
        .iter()
        .map(|l| {
            Json::from_pairs(vec![
                ("rank", Json::num(l.rank as f64)),
                ("lane", Json::num(l.lane as f64)),
                ("wait_ms", Json::num(ms(l.wait_us))),
                ("events", Json::num(l.events as f64)),
            ])
        })
        .collect();
    let stalls: Vec<Json> = s
        .stalls
        .iter()
        .map(|st| {
            Json::from_pairs(vec![
                ("rank", Json::num(st.rank as f64)),
                ("kind", Json::str(st.kind.clone())),
                ("name", Json::str(st.name.clone())),
                ("batch", st.batch.map_or(Json::Null, |b| Json::num(b as f64))),
                ("lane", st.lane.map_or(Json::Null, |l| Json::num(l as f64))),
                ("dur_ms", Json::num(ms(st.dur_us))),
            ])
        })
        .collect();
    let windows: Vec<Json> = s
        .windows
        .iter()
        .map(|w| {
            Json::from_pairs(vec![
                ("batch", Json::num(w.batch as f64)),
                ("span_ms", Json::num(ms(w.span_us()))),
                ("crit_rank", Json::num(w.crit_rank as f64)),
                ("crit_kind", Json::str(w.crit_kind.clone())),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("events", Json::num(s.events as f64)),
        ("ranks", Json::Arr(ranks)),
        ("lanes", Json::Arr(lanes)),
        ("top_stalls", Json::Arr(stalls)),
        ("batch_windows", Json::Arr(windows)),
    ])
}

/// One per-rank/per-kind regression found by the diff mode.
#[derive(Debug, Clone, PartialEq)]
pub struct Regression {
    pub rank: u64,
    pub kind: String,
    pub base_ms: f64,
    pub cur_ms: f64,
}

impl Regression {
    pub fn ratio(&self) -> f64 {
        self.cur_ms / self.base_ms.max(1e-9)
    }
}

/// Diff two summaries: a (rank, kind) cell regresses when the current
/// time exceeds baseline by more than `tolerance` (relative) *and* by
/// at least 1 ms (absolute — microsecond jitter is not a regression).
pub fn diff(current: &TraceSummary, baseline: &TraceSummary, tolerance: f64) -> Vec<Regression> {
    let base: BTreeMap<u64, &RankRollup> = baseline.ranks.iter().map(|r| (r.rank, r)).collect();
    let mut out = Vec::new();
    for r in &current.ranks {
        let Some(b) = base.get(&r.rank) else { continue };
        for (k, name) in KINDS.iter().enumerate() {
            let cur_ms = ms(r.by_kind_us[k]);
            let base_ms = ms(b.by_kind_us[k]);
            if cur_ms > base_ms * (1.0 + tolerance) && cur_ms - base_ms >= 1.0 {
                out.push(Regression {
                    rank: r.rank,
                    kind: name.to_string(),
                    base_ms,
                    cur_ms,
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// bench-gate

/// Direction of "better" for one bench metric, inferred from the last
/// segment of its dotted path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    LowerIsBetter,
    HigherIsBetter,
    Informational,
}

/// Infer the gate direction from a metric path. Matching is on the
/// leaf segment, case-insensitive: times/bytes/misses shrink, rates
/// grow, anything unrecognized is informational (never fails).
pub fn direction(path: &str) -> Direction {
    let leaf = path.rsplit('.').next().unwrap_or(path).to_ascii_lowercase();
    const LOWER: [&str; 10] = [
        "_ms", "_us", "_s", "seconds", "misses", "miss", "bytes", "rows", "lag", "stall",
    ];
    const HIGHER: [&str; 6] = ["qps", "throughput", "hit_rate", "hits", "speedup", "rate"];
    if HIGHER.iter().any(|h| leaf == *h || leaf.ends_with(h)) {
        return Direction::HigherIsBetter;
    }
    if LOWER.iter().any(|l| leaf == *l || leaf.ends_with(l)) {
        return Direction::LowerIsBetter;
    }
    Direction::Informational
}

/// Flatten every numeric leaf of a JSON document to `path → value`
/// with dotted paths (arrays index numerically).
pub fn flatten_numeric(doc: &Json) -> BTreeMap<String, f64> {
    fn walk(j: &Json, prefix: &str, out: &mut BTreeMap<String, f64>) {
        match j {
            Json::Num(n) => {
                out.insert(prefix.to_string(), *n);
            }
            Json::Obj(o) => {
                for (k, v) in o {
                    let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                    walk(v, &p, out);
                }
            }
            Json::Arr(a) => {
                for (i, v) in a.iter().enumerate() {
                    walk(v, &format!("{prefix}.{i}"), out);
                }
            }
            _ => {}
        }
    }
    let mut out = BTreeMap::new();
    walk(doc, "", &mut out);
    out
}

/// One compared metric in a gate run.
#[derive(Debug, Clone)]
pub struct GateRow {
    pub path: String,
    pub baseline: f64,
    pub current: f64,
    pub direction: Direction,
    pub failed: bool,
}

/// Result of a gate run: every matched metric, plus the verdict.
#[derive(Debug, Clone, Default)]
pub struct GateReport {
    pub rows: Vec<GateRow>,
    /// Paths present in only one of the two documents (reported, not
    /// failing — arms legitimately appear/disappear across runs).
    pub unmatched: Vec<String>,
}

impl GateReport {
    pub fn failures(&self) -> Vec<&GateRow> {
        self.rows.iter().filter(|r| r.failed).collect()
    }

    pub fn passed(&self) -> bool {
        self.rows.iter().all(|r| !r.failed)
    }
}

/// Compare `current` against `baseline` with a relative `tolerance`
/// (0.15 = 15%). Directional: lower-is-better metrics fail when
/// `current > baseline * (1 + tolerance)`, higher-is-better when
/// `current < baseline * (1 - tolerance)`. Near-zero baselines
/// (|baseline| < 1e-9) are informational — a ratio against zero means
/// nothing.
pub fn bench_gate(current: &Json, baseline: &Json, tolerance: f64) -> Result<GateReport> {
    if tolerance < 0.0 {
        bail!("tolerance must be >= 0, got {tolerance}");
    }
    let cur = flatten_numeric(current);
    let base = flatten_numeric(baseline);
    let mut report = GateReport::default();
    for (path, &b) in &base {
        let Some(&c) = cur.get(path) else {
            report.unmatched.push(path.clone());
            continue;
        };
        let dir = if b.abs() < 1e-9 { Direction::Informational } else { direction(path) };
        let failed = match dir {
            Direction::LowerIsBetter => c > b * (1.0 + tolerance),
            Direction::HigherIsBetter => c < b * (1.0 - tolerance),
            Direction::Informational => false,
        };
        report.rows.push(GateRow {
            path: path.clone(),
            baseline: b,
            current: c,
            direction: dir,
            failed,
        });
    }
    for path in cur.keys() {
        if !base.contains_key(path) {
            report.unmatched.push(path.clone());
        }
    }
    Ok(report)
}

/// Render a gate report for humans. Failures first, then the rest.
pub fn render_gate(report: &GateReport, tolerance: f64) -> String {
    let mut out = String::new();
    let fails = report.failures();
    out.push_str(&format!(
        "== bench-gate: {} metrics compared, {} regressions (tolerance {:.0}%) ==\n",
        report.rows.len(),
        fails.len(),
        tolerance * 100.0
    ));
    for r in &fails {
        out.push_str(&format!(
            "  FAIL {}: {} -> {} ({:+.1}%)\n",
            r.path,
            r.baseline,
            r.current,
            100.0 * (r.current - r.baseline) / r.baseline.abs().max(1e-9)
        ));
    }
    for r in &report.rows {
        if r.failed {
            continue;
        }
        let tag = match r.direction {
            Direction::Informational => "info",
            _ => "ok  ",
        };
        out.push_str(&format!("  {tag} {}: {} -> {}\n", r.path, r.baseline, r.current));
    }
    for p in &report.unmatched {
        out.push_str(&format!("  only-one-side {p}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::{
        ObsEvent, TraceTrack, KIND_BARRIER_WAIT, KIND_COMPUTE, KIND_WIRE_WAIT, LANE_NONE,
        NO_BATCH_U64,
    };
    use crate::obs::{chrome_trace_json, MetricsSnapshot, ObsReport};

    fn ev(batch: u64, kind: u8, lane: u8, t0: u64, t1: u64) -> ObsEvent {
        ObsEvent {
            batch,
            kind,
            lane,
            name_idx: 0,
            t0_us: t0,
            t1_us: t1,
        }
    }

    fn two_rank_report() -> ObsReport {
        ObsReport {
            tracks: vec![
                TraceTrack {
                    rank: 0,
                    thread: "w".into(),
                    dropped: 0,
                    names: vec!["s".into()],
                    events: vec![
                        ev(0, KIND_COMPUTE, LANE_NONE, 0, 1_000),
                        ev(0, KIND_WIRE_WAIT, 1, 1_000, 4_000),
                        ev(1, KIND_COMPUTE, LANE_NONE, 4_000, 5_000),
                        ev(NO_BATCH_U64, KIND_BARRIER_WAIT, LANE_NONE, 5_000, 5_500),
                    ],
                },
                TraceTrack {
                    rank: 1,
                    thread: "w".into(),
                    dropped: 0,
                    names: vec!["s".into()],
                    events: vec![
                        ev(0, KIND_COMPUTE, LANE_NONE, 0, 2_000),
                        ev(1, KIND_WIRE_WAIT, 0, 2_000, 9_000),
                    ],
                },
            ],
            metrics: MetricsSnapshot::default(),
        }
    }

    #[test]
    fn rollups_stalls_and_critical_path() {
        let doc = chrome_trace_json(&two_rank_report());
        let s = analyze(&doc).expect("analyze");
        assert_eq!(s.events, 6);
        assert_eq!(s.ranks.len(), 2);
        let r0 = &s.ranks[0];
        assert_eq!(r0.rank, 0);
        assert_eq!(r0.by_kind_us, [2_000, 0, 3_000, 500]);
        let r1 = &s.ranks[1];
        assert_eq!(r1.by_kind_us, [2_000, 0, 7_000, 0]);
        // Lane rollups: only wire-wait events with a lane.
        assert_eq!(s.lanes.len(), 2);
        assert_eq!((s.lanes[0].rank, s.lanes[0].lane, s.lanes[0].wait_us), (0, 1, 3_000));
        assert_eq!((s.lanes[1].rank, s.lanes[1].lane, s.lanes[1].wait_us), (1, 0, 7_000));
        // Top stalls descend by duration; the longest is rank 1's
        // 7 ms wire wait on batch 1.
        assert_eq!(s.stalls[0].dur_us, 7_000);
        assert_eq!(s.stalls[0].rank, 1);
        assert_eq!(s.stalls[0].batch, Some(1));
        // Batch windows: batch 0 spans 0..4000 closed by rank 0's wire
        // wait; batch 1 spans 2000..9000 closed by rank 1.
        assert_eq!(s.windows.len(), 2);
        assert_eq!(s.windows[0].span_us(), 4_000);
        assert_eq!(s.windows[0].crit_rank, 0);
        assert_eq!(s.windows[1].span_us(), 7_000);
        assert_eq!(s.windows[1].crit_rank, 1);
        assert_eq!(s.crit_batches_by_rank.get(&0), Some(&1));
        assert_eq!(s.crit_batches_by_rank.get(&1), Some(&1));
        // Both renderers produce non-empty, parseable output.
        let text = render_text(&s);
        assert!(text.contains("per-rank stall attribution"));
        assert!(text.contains("critical path"));
        let j = render_json(&s).to_string();
        let back = crate::util::json::parse(&j).expect("render_json parses");
        assert_eq!(back.get("ranks").as_arr().map(<[Json]>::len), Some(2));
    }

    #[test]
    fn diff_flags_only_real_regressions() {
        let doc = chrome_trace_json(&two_rank_report());
        let base = analyze(&doc).unwrap();
        let mut cur = base.clone();
        // Inflate rank 1's wire-wait by 2x (7 ms → 14 ms): past 15%
        // tolerance and past the 1 ms absolute floor.
        cur.ranks[1].by_kind_us[2] *= 2;
        // Inflate rank 0's barrier wait by 2x but only 0.5 ms → under
        // the absolute floor, not a regression.
        cur.ranks[0].by_kind_us[3] *= 2;
        let regs = diff(&cur, &base, 0.15);
        assert_eq!(regs.len(), 1);
        assert_eq!((regs[0].rank, regs[0].kind.as_str()), (1, "wire-wait"));
        assert!((regs[0].ratio() - 2.0).abs() < 1e-9);
        assert!(diff(&base, &base, 0.15).is_empty(), "self-diff is clean");
    }

    #[test]
    fn analyze_rejects_non_trace_json() {
        let doc = crate::util::json::parse("{\"foo\": 1}").unwrap();
        assert!(analyze(&doc).is_err());
    }

    #[test]
    fn directions_are_sensible() {
        assert_eq!(direction("serve.full.p99_ms"), Direction::LowerIsBetter);
        assert_eq!(direction("serve.full.qps"), Direction::HigherIsBetter);
        assert_eq!(direction("serve.full.fetched_bytes"), Direction::LowerIsBetter);
        assert_eq!(direction("serve.full.deadline_misses"), Direction::LowerIsBetter);
        assert_eq!(direction("serve.full.hit_rate"), Direction::HigherIsBetter);
        assert_eq!(direction("serve.arms.0"), Direction::Informational);
        assert_eq!(direction("served"), Direction::Informational);
    }

    #[test]
    fn bench_gate_catches_injected_2x_slowdown() {
        let baseline = crate::util::json::parse(
            r#"{"serve": {"full": {"p50_ms": 2.0, "p99_ms": 8.0, "qps": 500.0,
                 "deadline_misses": 1, "served": 256}}}"#,
        )
        .unwrap();
        // Identical current: gate passes.
        let clean = bench_gate(&baseline, &baseline, 0.15).unwrap();
        assert!(clean.passed(), "self-compare must pass");
        // Inject a 2x p99 slowdown.
        let current = crate::util::json::parse(
            r#"{"serve": {"full": {"p50_ms": 2.0, "p99_ms": 16.0, "qps": 500.0,
                 "deadline_misses": 1, "served": 256}}}"#,
        )
        .unwrap();
        let gated = bench_gate(&current, &baseline, 0.15).unwrap();
        assert!(!gated.passed(), "a 2x p99 regression must fail the gate");
        let fails = gated.failures();
        assert_eq!(fails.len(), 1);
        assert_eq!(fails[0].path, "serve.full.p99_ms");
        // A qps collapse also fails (higher-is-better direction).
        let slow = crate::util::json::parse(
            r#"{"serve": {"full": {"p50_ms": 2.0, "p99_ms": 8.0, "qps": 200.0,
                 "deadline_misses": 1, "served": 256}}}"#,
        )
        .unwrap();
        assert!(!bench_gate(&slow, &baseline, 0.15).unwrap().passed());
        // Within tolerance: 10% slower p99 passes a 15% gate.
        let near = crate::util::json::parse(
            r#"{"serve": {"full": {"p50_ms": 2.0, "p99_ms": 8.8, "qps": 500.0,
                 "deadline_misses": 1, "served": 256}}}"#,
        )
        .unwrap();
        assert!(bench_gate(&near, &baseline, 0.15).unwrap().passed());
        // Renderer mentions the failing path.
        assert!(render_gate(&gated, 0.15).contains("serve.full.p99_ms"));
    }

    #[test]
    fn gate_handles_shape_drift_and_zero_baselines() {
        let baseline =
            crate::util::json::parse(r#"{"a": {"p99_ms": 0.0, "gone_ms": 3.0}}"#).unwrap();
        let current =
            crate::util::json::parse(r#"{"a": {"p99_ms": 99.0, "new_ms": 1.0}}"#).unwrap();
        let rep = bench_gate(&current, &baseline, 0.15).unwrap();
        // Zero baseline → informational, not an infinite-ratio fail.
        assert!(rep.passed());
        assert_eq!(rep.unmatched, vec!["a.gone_ms".to_string(), "a.new_ms".to_string()]);
    }
}
