//! Per-rank span recorder: thread-local event buffers, no locks on the
//! hot path.
//!
//! Every instrumented region is a [`span`] guard: the stage bodies of
//! `exec::BatchPlan` open compute/marshal spans, the collectives open
//! **blocked-on-recv stall spans** (wire-wait on the data lanes,
//! barrier-wait on the barrier lanes), and the TCP reader threads
//! record per-frame receive spans — so every microsecond of a worker's
//! epoch is attributed to compute, marshal, wire-wait, or barrier-wait.
//!
//! Zero-cost when disabled: a thread that never called
//! [`thread_register`] has no buffer, and [`span`] returns an inert
//! guard **without reading the clock**. Registration happens per epoch
//! and only when `train.trace` is set, so untraced runs never pay for
//! the instrumentation. Recording itself never touches the training
//! math — spans read clocks and push into a thread-local `Vec`; they
//! cannot change a seeded schedule or a float fold, which is how
//! losses stay byte-identical with tracing on vs off (pinned in
//! `tests/test_obs_trace.rs`).
//!
//! Threads that outlive an epoch (the TCP reader/demux threads) and
//! overflow segments flush their finished [`TraceTrack`]s into a
//! process-global [`sink_push`] buffer, drained into the epoch's
//! [`TraceBlob`](super::TraceBlob) alongside the thread-local flush.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Instant, SystemTime};

use anyhow::Result;

use crate::net::codec::{ByteReader, ByteWriter, WireCodec};

/// Span kinds: what a slice of a rank's wall clock was spent on.
pub const KIND_COMPUTE: u8 = 0;
pub const KIND_MARSHAL: u8 = 1;
pub const KIND_WIRE_WAIT: u8 = 2;
pub const KIND_BARRIER_WAIT: u8 = 3;

/// Lane tag for spans not tied to a protocol lane (compute/marshal).
pub const LANE_NONE: u8 = 0xFF;

/// Batch tag for events recorded outside any batch (barriers, setup).
pub const NO_BATCH_U64: u64 = u64::MAX;

/// Cap per segment: a runaway epoch degrades to a drop counter instead
/// of unbounded memory.
const MAX_EVENTS_PER_SEGMENT: usize = 1 << 16;

pub fn kind_name(kind: u8) -> &'static str {
    match kind {
        KIND_COMPUTE => "compute",
        KIND_MARSHAL => "marshal",
        KIND_WIRE_WAIT => "wire-wait",
        KIND_BARRIER_WAIT => "barrier-wait",
        _ => "unknown",
    }
}

/// One recorded span. `name_idx` points into the owning
/// [`TraceTrack::names`] table; timestamps are microseconds on the
/// leader's clock once [`rebase_tracks`] applied the handshake offset.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsEvent {
    pub batch: u64,
    pub kind: u8,
    pub lane: u8,
    pub name_idx: u16,
    pub t0_us: u64,
    pub t1_us: u64,
}

/// All events one (rank, thread) recorded: one track of the exported
/// Chrome trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceTrack {
    pub rank: u32,
    pub thread: String,
    /// Events lost to the per-segment cap (visible, never silent).
    pub dropped: u64,
    pub names: Vec<String>,
    pub events: Vec<ObsEvent>,
}

impl WireCodec for ObsEvent {
    fn encode(&self, w: &mut ByteWriter) {
        w.u64(self.batch);
        w.u8(self.kind);
        w.u8(self.lane);
        w.u16(self.name_idx);
        w.u64(self.t0_us);
        w.u64(self.t1_us);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<ObsEvent> {
        Ok(ObsEvent {
            batch: r.u64()?,
            kind: r.u8()?,
            lane: r.u8()?,
            name_idx: r.u16()?,
            t0_us: r.u64()?,
            t1_us: r.u64()?,
        })
    }
}

/// Encoded [`ObsEvent`] size — the element bound `seq_len` validates
/// declared counts against.
const OBS_EVENT_BYTES: usize = 28;

impl WireCodec for TraceTrack {
    fn encode(&self, w: &mut ByteWriter) {
        w.u32(self.rank);
        w.str(&self.thread);
        w.u64(self.dropped);
        w.u32(self.names.len() as u32);
        for n in &self.names {
            w.str(n);
        }
        w.u32(self.events.len() as u32);
        for e in &self.events {
            e.encode(w);
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<TraceTrack> {
        let rank = r.u32()?;
        let thread = r.str()?;
        let dropped = r.u64()?;
        let n = r.seq_len(4)?; // each name carries at least its u32 length
        let mut names = Vec::with_capacity(n);
        for _ in 0..n {
            names.push(r.str()?);
        }
        let n = r.seq_len(OBS_EVENT_BYTES)?;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            events.push(ObsEvent::decode(r)?);
        }
        Ok(TraceTrack {
            rank,
            thread,
            dropped,
            names,
            events,
        })
    }
}

// ---------------------------------------------------------------------------
// Global switches and clocks

/// Sticky process-global enable: set (never cleared) when any traced
/// run registers a thread. Gates the recording the thread-local buffer
/// cannot — metric ticks and the TCP reader threads, which have no
/// epoch scope. Sticky-on means a later untraced run in the same
/// process pays those ticks; that is cosmetic only (counters never
/// feed the training math).
static OBS_ON: AtomicBool = AtomicBool::new(false);

pub fn enabled() -> bool {
    OBS_ON.load(Ordering::Relaxed)
}

/// Turn recording on for this process (sticky; `false` is ignored so a
/// later epoch cannot yank buffers out from under live reader threads).
pub fn set_enabled(on: bool) {
    if on {
        OBS_ON.store(true, Ordering::Relaxed);
    }
}

/// Clock origin: unix micros anchored to a monotonic instant, so
/// every timestamp in a process is monotonic *and* comparable across
/// processes once the handshake offset is applied.
static ORIGIN: OnceLock<(Instant, u64)> = OnceLock::new();

fn origin() -> &'static (Instant, u64) {
    ORIGIN.get_or_init(|| {
        let unix = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        (Instant::now(), unix)
    })
}

/// Microseconds since the unix epoch on this process's clock.
pub fn now_us() -> u64 {
    let &(anchor, unix) = origin();
    unix + anchor.elapsed().as_micros() as u64
}

/// leader-clock − local-clock offset, estimated at TCP handshake time
/// (`net::tcp::dial` reads the leader's timestamp from the handshake
/// reply). Zero for in-process transports and on the leader itself.
static CLOCK_OFFSET_US: AtomicI64 = AtomicI64::new(0);

pub fn set_clock_offset(us: i64) {
    CLOCK_OFFSET_US.store(us, Ordering::Relaxed);
}

pub fn clock_offset_us() -> i64 {
    CLOCK_OFFSET_US.load(Ordering::Relaxed)
}

/// Shift every timestamp of `tracks` by `offset_us` (saturating at the
/// epoch bounds) — applied once, on the worker, when its epoch blob is
/// built, so the leader merges already-aligned tracks.
pub fn rebase_tracks(tracks: &mut [TraceTrack], offset_us: i64) {
    if offset_us == 0 {
        return;
    }
    let shift = |t: u64| (t as i128 + offset_us as i128).clamp(0, u64::MAX as i128) as u64;
    for t in tracks {
        for e in &mut t.events {
            e.t0_us = shift(e.t0_us);
            e.t1_us = shift(e.t1_us);
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-local recording

struct ThreadBuf {
    rank: u32,
    thread: String,
    batch: u64,
    /// Intern table; only grows, so earlier segments' `name_idx` values
    /// stay valid when a later segment's table supersedes theirs.
    names: Vec<&'static str>,
    events: Vec<ObsEvent>,
    dropped: u64,
    /// Closed segments (one per rank this thread played — the
    /// sequential driver plays them all).
    done: Vec<TraceTrack>,
}

impl ThreadBuf {
    fn name_idx(&mut self, name: &'static str) -> u16 {
        if let Some(i) = self.names.iter().position(|&n| std::ptr::eq(n, name) || n == name) {
            return i as u16;
        }
        self.names.push(name);
        (self.names.len() - 1) as u16
    }

    fn record(&mut self, kind: u8, lane: u8, name: &'static str, t0_us: u64, t1_us: u64) {
        if self.events.len() >= MAX_EVENTS_PER_SEGMENT {
            self.dropped += 1;
            return;
        }
        let name_idx = self.name_idx(name);
        self.events.push(ObsEvent {
            batch: self.batch,
            kind,
            lane,
            name_idx,
            t0_us,
            t1_us,
        });
    }

    /// Close the current segment into `done`, merging with an earlier
    /// segment of the same rank (the name table only grows, so the
    /// latest table covers every earlier index).
    fn close_segment(&mut self) {
        if self.events.is_empty() && self.dropped == 0 {
            return;
        }
        let names: Vec<String> = self.names.iter().map(|s| s.to_string()).collect();
        let events = std::mem::take(&mut self.events);
        let dropped = std::mem::take(&mut self.dropped);
        if let Some(t) = self.done.iter_mut().find(|t| t.rank == self.rank) {
            t.names = names;
            t.events.extend(events);
            t.dropped += dropped;
        } else {
            self.done.push(TraceTrack {
                rank: self.rank,
                thread: self.thread.clone(),
                dropped,
                names,
                events,
            });
        }
    }
}

thread_local! {
    static BUF: RefCell<Option<ThreadBuf>> = const { RefCell::new(None) };
}

/// Arm recording on this thread for the epoch. Call only when
/// `train.trace` is set — an unregistered thread's spans are inert.
/// Also sets the sticky process-global enable.
pub fn thread_register(rank: u32, thread: &str) {
    set_enabled(true);
    BUF.with(|b| {
        *b.borrow_mut() = Some(ThreadBuf {
            rank,
            thread: thread.to_string(),
            batch: NO_BATCH_U64,
            names: Vec::new(),
            events: Vec::new(),
            dropped: 0,
            done: Vec::new(),
        });
    });
}

/// Retag this thread's subsequent spans with `rank` — the sequential
/// driver plays every rank on one thread and switches per phase.
pub fn set_rank(rank: u32) {
    BUF.with(|b| {
        if let Some(buf) = b.borrow_mut().as_mut() {
            if buf.rank != rank {
                buf.close_segment();
                buf.rank = rank;
            }
        }
    });
}

/// Tag subsequent spans with the batch index ([`NO_BATCH_U64`] between
/// batches).
pub fn set_batch(batch: u64) {
    // Feed /healthz batch progress: one relaxed load when the
    // telemetry plane is unarmed, one extra store per batch when armed
    // (works even when no thread is registered, i.e. without --trace).
    if batch != NO_BATCH_U64 {
        super::http::health_note_batch(batch as i64);
    }
    BUF.with(|b| {
        if let Some(buf) = b.borrow_mut().as_mut() {
            buf.batch = batch;
        }
    });
}

/// The batch this thread is currently attributed to, if recording and
/// inside one (the `log!` prefix reads it).
pub fn current_batch() -> Option<u64> {
    BUF.with(|b| {
        b.borrow()
            .as_ref()
            .map(|buf| buf.batch)
            .filter(|&bi| bi != NO_BATCH_U64)
    })
}

/// Close this thread's recording and hand back its tracks (the thread
/// is unregistered afterwards; the next epoch re-registers).
pub fn thread_flush() -> Vec<TraceTrack> {
    BUF.with(|b| match b.borrow_mut().take() {
        Some(mut buf) => {
            buf.close_segment();
            buf.done
        }
        None => Vec::new(),
    })
}

/// RAII span guard: records `[construction, drop]` on the thread's
/// buffer. Inert — no clock read at all — when the thread is not
/// registered.
pub struct Span {
    t0_us: u64,
    kind: u8,
    lane: u8,
    name: &'static str,
    active: bool,
}

/// Open a span. The `name` must be `'static` so recording never
/// allocates on the hot path.
pub fn span(kind: u8, lane: u8, name: &'static str) -> Span {
    let active = BUF.with(|b| b.borrow().is_some());
    Span {
        t0_us: if active { now_us() } else { 0 },
        kind,
        lane,
        name,
        active,
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let t1_us = now_us();
        BUF.with(|b| {
            if let Some(buf) = b.borrow_mut().as_mut() {
                buf.record(self.kind, self.lane, self.name, self.t0_us, t1_us);
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Process-global sink (threads without an epoch scope)

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

static SINK: Mutex<Vec<TraceTrack>> = Mutex::new(Vec::new());

/// Park a finished track for the next epoch-end collection — used by
/// the TCP reader threads, which outlive epochs and own no port to
/// ship through.
pub fn sink_push(track: TraceTrack) {
    if track.events.is_empty() && track.dropped == 0 {
        return;
    }
    lock(&SINK).push(track);
}

/// Drain everything parked since the last drain.
pub fn take_sink_tracks() -> Vec<TraceTrack> {
    std::mem::take(&mut *lock(&SINK))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::codec::{decode_message, encode_message};

    #[test]
    fn unregistered_spans_are_inert() {
        // No registration: the span must record nothing, and a later
        // registration must not inherit ghost events.
        {
            let _s = span(KIND_COMPUTE, LANE_NONE, "ghost");
        }
        thread_register(3, "test");
        let tracks = thread_flush();
        assert!(tracks.is_empty(), "no spans were opened while registered: {tracks:?}");
        assert!(thread_flush().is_empty(), "flush must unregister");
    }

    #[test]
    fn spans_record_batch_kind_and_name() {
        thread_register(1, "test");
        set_batch(4);
        {
            let _s = span(KIND_MARSHAL, LANE_NONE, "fwd-marshal");
        }
        set_batch(NO_BATCH_U64);
        {
            let _s = span(KIND_BARRIER_WAIT, 2, "barrier");
        }
        let tracks = thread_flush();
        assert_eq!(tracks.len(), 1);
        let t = &tracks[0];
        assert_eq!(t.rank, 1);
        assert_eq!(t.thread, "test");
        assert_eq!(t.events.len(), 2);
        let e0 = &t.events[0];
        assert_eq!((e0.batch, e0.kind, e0.lane), (4, KIND_MARSHAL, LANE_NONE));
        assert_eq!(t.names[e0.name_idx as usize], "fwd-marshal");
        assert!(e0.t1_us >= e0.t0_us, "span end before start");
        let e1 = &t.events[1];
        assert_eq!((e1.batch, e1.kind, e1.lane), (NO_BATCH_U64, KIND_BARRIER_WAIT, 2));
    }

    #[test]
    fn set_rank_splits_tracks_and_merges_revisits() {
        // The sequential driver's pattern: one thread plays rank 0,
        // then rank 1, then rank 0 again. Two tracks, rank 0's merged.
        thread_register(0, "driver");
        {
            let _s = span(KIND_COMPUTE, LANE_NONE, "a");
        }
        set_rank(1);
        {
            let _s = span(KIND_COMPUTE, LANE_NONE, "b");
        }
        set_rank(0);
        {
            let _s = span(KIND_COMPUTE, LANE_NONE, "c");
        }
        let mut tracks = thread_flush();
        tracks.sort_by_key(|t| t.rank);
        assert_eq!(tracks.len(), 2, "{tracks:?}");
        assert_eq!(tracks[0].rank, 0);
        assert_eq!(tracks[0].events.len(), 2, "rank 0 revisit must merge");
        assert_eq!(tracks[1].rank, 1);
        assert_eq!(tracks[1].events.len(), 1);
        // The merged track's (grown) name table resolves both events.
        for e in &tracks[0].events {
            assert!(tracks[0].names.get(e.name_idx as usize).is_some());
        }
    }

    #[test]
    fn rebase_shifts_and_saturates() {
        let mut tracks = vec![TraceTrack {
            rank: 0,
            thread: "t".into(),
            dropped: 0,
            names: vec!["x".into()],
            events: vec![ObsEvent {
                batch: 0,
                kind: KIND_COMPUTE,
                lane: LANE_NONE,
                name_idx: 0,
                t0_us: 100,
                t1_us: 200,
            }],
        }];
        rebase_tracks(&mut tracks, 50);
        assert_eq!((tracks[0].events[0].t0_us, tracks[0].events[0].t1_us), (150, 250));
        rebase_tracks(&mut tracks, -1000);
        assert_eq!(
            (tracks[0].events[0].t0_us, tracks[0].events[0].t1_us),
            (0, 0),
            "negative overshoot must clamp, not wrap"
        );
    }

    #[test]
    fn track_codec_round_trips_and_rejects_truncation() {
        let track = TraceTrack {
            rank: 7,
            thread: "net-rx-from-2".into(),
            dropped: 3,
            names: vec!["rx".into(), "héta".into()],
            events: vec![
                ObsEvent {
                    batch: NO_BATCH_U64,
                    kind: KIND_WIRE_WAIT,
                    lane: 1,
                    name_idx: 0,
                    t0_us: 10,
                    t1_us: 20,
                },
                ObsEvent {
                    batch: 5,
                    kind: KIND_COMPUTE,
                    lane: LANE_NONE,
                    name_idx: 1,
                    t0_us: u64::MAX - 1,
                    t1_us: u64::MAX,
                },
            ],
        };
        let bytes = encode_message(&track);
        let back: TraceTrack = decode_message(&bytes).unwrap();
        assert_eq!(back, track);
        for cut in 0..bytes.len() {
            assert!(
                decode_message::<TraceTrack>(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must be rejected",
                bytes.len()
            );
        }
    }

    #[test]
    fn kind_names_cover_every_kind() {
        assert_eq!(kind_name(KIND_COMPUTE), "compute");
        assert_eq!(kind_name(KIND_MARSHAL), "marshal");
        assert_eq!(kind_name(KIND_WIRE_WAIT), "wire-wait");
        assert_eq!(kind_name(KIND_BARRIER_WAIT), "barrier-wait");
        assert_eq!(kind_name(99), "unknown");
    }
}
