//! Flight recorder for the distributed runtime (PR 6): cross-rank
//! tracing, stall attribution, and metrics — hand-rolled, no external
//! crates, always compiled in.
//!
//! Three layers:
//!
//! - [`recorder`] — per-rank, thread-local span recording. Stage
//!   bodies, collectives, and the TCP reader threads open RAII
//!   [`span`]s tagged `(batch, kind, lane)`, where kind is one of
//!   compute / marshal / wire-wait / barrier-wait. Zero-cost when
//!   disabled: unregistered threads get inert guards with no clock
//!   read.
//! - [`metrics`] — a [`MetricsRegistry`] of counters, high-water
//!   gauges, and histogram summaries (wire bytes per lane,
//!   per-node-type cache hit/miss, staleness-window occupancy,
//!   grad-version lag), snapshotted per epoch.
//! - [`export`] — Chrome trace-event / Perfetto JSON (`--trace
//!   out.json`), one track per rank×thread, stall spans colored by
//!   lane.
//!
//! Two more layers ride on those (PR 10):
//!
//! - [`http`] — the live telemetry plane: per-rank `/metrics`
//!   (Prometheus text exposition over the registry's cumulative
//!   [`MetricsRegistry::peek`] view), `/healthz` (rank/role/progress +
//!   per-peer heartbeat lag), and `/buildinfo`, armed with
//!   `--metrics-addr host:port`.
//! - [`analyze`] — offline analytics over the exported trace
//!   (`heta analyze`): per-rank/per-lane stall rollups, top-N stalls,
//!   critical-path extraction, baseline diffing — plus the
//!   `heta bench-gate` perf-regression comparator.
//!
//! Cross-process collection: each worker packs its epoch into a
//! [`TraceBlob`] (serialized via the existing `WireCodec`) and ships
//! it to the leader on the stats path at epoch end; TCP workers
//! clock-align first using the offset estimated from the handshake
//! reply timestamp. The leader merges all blobs into
//! [`EpochReport::obs`](crate::metrics::EpochReport::obs).
//!
//! The hard invariant — pinned by `tests/test_obs_trace.rs` through
//! the `tests/common` equivalence harness — is that losses are
//! **byte-identical** with tracing on vs off, for both engines over
//! both transports: observability is passive. The blob exchange runs
//! unconditionally (empty blobs when disabled) so the protocol shape
//! never depends on the trace flag.
//!
//! See `docs/OBSERVABILITY.md` for the user-facing guide.

pub mod analyze;
pub mod export;
pub mod http;
pub mod logging;
pub mod metrics;
pub mod recorder;

use anyhow::Result;

use crate::net::codec::{ByteReader, ByteWriter, WireCodec};

pub use export::{chrome_trace_json, export_chrome};
pub use http::{
    health_register_peer, health_set_epoch, health_set_identity, HealthState, TelemetryServer,
};
pub use logging::{
    log_enabled, log_line, set_log_format, set_log_level, set_log_rank, LogFormat, LogLevel,
};
pub use metrics::{
    cache_obs_base, counter_add, gauge_max, gauge_set, hist_observe, peek, record_cache_counters,
    record_cache_obs, record_serve_summary, snapshot_and_reset, HistSummary, LiveView,
    MetricsRegistry, MetricsSnapshot, BUCKET_BOUNDS,
};
pub use recorder::{
    clock_offset_us, current_batch, enabled, kind_name, now_us, rebase_tracks, set_batch,
    set_clock_offset, set_enabled, set_rank, sink_push, span, take_sink_tracks, thread_flush,
    thread_register, ObsEvent, Span, TraceTrack, KIND_BARRIER_WAIT, KIND_COMPUTE, KIND_MARSHAL,
    KIND_WIRE_WAIT, LANE_NONE, NO_BATCH_U64,
};

// `crate::log!` is #[macro_export]ed at the crate root; re-export it
// here so downstream code can also write `obs::log!`.
pub use crate::log;

/// The observability slice of an epoch: every rank's trace tracks plus
/// the merged metrics snapshot. Lives on
/// [`EpochReport`](crate::metrics::EpochReport) and merges across
/// epochs via [`absorb`](crate::metrics::EpochReport::absorb).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsReport {
    pub tracks: Vec<TraceTrack>,
    pub metrics: MetricsSnapshot,
}

impl ObsReport {
    pub fn is_empty(&self) -> bool {
        self.tracks.is_empty() && self.metrics.is_empty()
    }

    pub fn merge(&mut self, other: &ObsReport) {
        self.tracks.extend(other.tracks.iter().cloned());
        self.metrics.merge(&other.metrics);
    }

    /// Seconds attributed to each span kind (indexed by `KIND_*`),
    /// summed over every track — the acceptance check that per-worker
    /// span sums are consistent with `EpochReport` stage totals reads
    /// this.
    pub fn seconds_by_kind(&self) -> [f64; 4] {
        let mut out = [0.0; 4];
        for t in &self.tracks {
            for e in &t.events {
                if let Some(slot) = out.get_mut(e.kind as usize) {
                    *slot += e.t1_us.saturating_sub(e.t0_us) as f64 / 1e6;
                }
            }
        }
        out
    }

    /// Seconds by kind for one rank only.
    pub fn seconds_by_kind_for_rank(&self, rank: u32) -> [f64; 4] {
        let mut out = [0.0; 4];
        for t in self.tracks.iter().filter(|t| t.rank == rank) {
            for e in &t.events {
                if let Some(slot) = out.get_mut(e.kind as usize) {
                    *slot += e.t1_us.saturating_sub(e.t0_us) as f64 / 1e6;
                }
            }
        }
        out
    }
}

/// One rank's epoch-end observability payload: its trace tracks
/// (already clock-aligned to the leader) and its metrics snapshot.
/// Sent leader-ward on the stats path by both engines, in both
/// transports — empty when tracing is off, but always sent, so the
/// message schedule is identical either way.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceBlob {
    pub rank: u32,
    pub tracks: Vec<TraceTrack>,
    pub metrics: MetricsSnapshot,
}

impl TraceBlob {
    /// Drain everything this rank recorded this epoch: the calling
    /// thread's buffer, any parked tracks from helper threads (TCP
    /// readers), and the metrics registry. Track timestamps are
    /// rebased onto the leader's clock using the handshake offset
    /// (zero for in-process transports).
    ///
    /// Draining the shared sink/registry is racy only across *ranks in
    /// one process* (loopback tests); that is benign — tracks carry
    /// their own rank, and the leader sums metrics over all blobs, so
    /// nothing is lost or double-counted whichever rank drains first.
    pub fn collect(rank: u32) -> TraceBlob {
        let mut tracks = recorder::thread_flush();
        tracks.extend(recorder::take_sink_tracks());
        recorder::rebase_tracks(&mut tracks, recorder::clock_offset_us());
        TraceBlob {
            rank,
            tracks,
            metrics: metrics::snapshot_and_reset(),
        }
    }

    /// Fold this blob into the epoch report the leader is building.
    pub fn merge_into(&self, obs: &mut ObsReport) {
        obs.tracks.extend(self.tracks.iter().cloned());
        obs.metrics.merge(&self.metrics);
    }
}

impl WireCodec for TraceBlob {
    fn encode(&self, w: &mut ByteWriter) {
        w.u32(self.rank);
        w.u32(self.tracks.len() as u32);
        for t in &self.tracks {
            t.encode(w);
        }
        self.metrics.encode(w);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<TraceBlob> {
        let rank = r.u32()?;
        // A track is at least 4 (rank) + 4 (thread len) + 8 (dropped)
        // + 4 + 4 (empty name/event counts) bytes.
        let n = r.seq_len(24)?;
        let mut tracks = Vec::with_capacity(n);
        for _ in 0..n {
            tracks.push(TraceTrack::decode(r)?);
        }
        let metrics = MetricsSnapshot::decode(r)?;
        Ok(TraceBlob {
            rank,
            tracks,
            metrics,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::codec::{decode_message, encode_message};
    use recorder::{ObsEvent, KIND_COMPUTE, KIND_WIRE_WAIT, LANE_NONE};

    fn track(rank: u32, kind: u8, dur_us: u64) -> TraceTrack {
        TraceTrack {
            rank,
            thread: "t".into(),
            dropped: 0,
            names: vec!["e".into()],
            events: vec![ObsEvent {
                batch: 0,
                kind,
                lane: LANE_NONE,
                name_idx: 0,
                t0_us: 0,
                t1_us: dur_us,
            }],
        }
    }

    #[test]
    fn obs_report_merge_and_kind_sums() {
        let mut a = ObsReport {
            tracks: vec![track(0, KIND_COMPUTE, 1_000_000)],
            metrics: MetricsSnapshot {
                counters: vec![("c".into(), 1)],
                ..Default::default()
            },
        };
        let b = ObsReport {
            tracks: vec![track(1, KIND_WIRE_WAIT, 500_000)],
            metrics: MetricsSnapshot {
                counters: vec![("c".into(), 2)],
                ..Default::default()
            },
        };
        assert!(!a.is_empty());
        a.merge(&b);
        assert_eq!(a.tracks.len(), 2);
        assert_eq!(a.metrics.counter("c"), 3);
        let by_kind = a.seconds_by_kind();
        assert_eq!(by_kind[KIND_COMPUTE as usize], 1.0);
        assert_eq!(by_kind[KIND_WIRE_WAIT as usize], 0.5);
        assert_eq!(a.seconds_by_kind_for_rank(1)[KIND_WIRE_WAIT as usize], 0.5);
        assert_eq!(a.seconds_by_kind_for_rank(1)[KIND_COMPUTE as usize], 0.0);
    }

    #[test]
    fn trace_blob_codec_round_trips_and_rejects_truncation() {
        let blob = TraceBlob {
            rank: 2,
            tracks: vec![track(2, KIND_COMPUTE, 42), track(2, KIND_WIRE_WAIT, 7)],
            metrics: MetricsSnapshot {
                counters: vec![("wire.lane1.rx_bytes".into(), 99)],
                gauges: vec![("staleness.open".into(), 1.0)],
                hists: Vec::new(),
            },
        };
        let bytes = encode_message(&blob);
        let back: TraceBlob = decode_message(&bytes).unwrap();
        assert_eq!(back, blob);
        for cut in 0..bytes.len() {
            assert!(
                decode_message::<TraceBlob>(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} must be rejected",
                bytes.len()
            );
        }
        // The tracing-off shape: an empty blob still round-trips.
        let empty = TraceBlob {
            rank: 5,
            ..Default::default()
        };
        let bytes = encode_message(&empty);
        assert_eq!(decode_message::<TraceBlob>(&bytes).unwrap(), empty);
    }
}
