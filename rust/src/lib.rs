//! # Heta — distributed training of heterogeneous graph neural networks
//!
//! A three-layer reproduction of *Heta: Distributed Training of Heterogeneous
//! Graph Neural Networks* (Zhong et al., 2024):
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator: the
//!   Relation-Aggregation-First (RAF) execution engine, meta-partitioning,
//!   the miss-penalty-aware feature cache, and the vanilla (DGL/GraphLearn
//!   style) baseline engine, together with every substrate they depend on
//!   (heterogeneous graph storage, synthetic dataset generators, samplers,
//!   a simulated cluster transport, a distributed KV store, sparse Adam).
//! * **Layer 2 (python/compile/model.py)** — the HGNN compute graphs
//!   (R-GCN, R-GAT, HGT) in JAX, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   relation-aggregation hot spot, lowered into the same HLO.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! models once, and the Rust coordinator loads and executes the artifacts
//! through the PJRT C API (`xla` crate).

pub mod util;
pub mod hetgraph;
pub mod datagen;
pub mod partition;
pub mod sampling;
pub mod comm;
pub mod kvstore;
pub mod cache;
pub mod optim;
pub mod metrics;
pub mod config;
pub mod runtime;
pub mod coordinator;
