// Repo-idiom allowances: seeded numeric code mixes shift/xor seeds and
// threads wide argument lists through engine internals by design.
#![allow(
    clippy::precedence,
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::type_complexity,
    clippy::manual_range_contains
)]

//! # Heta — distributed training of heterogeneous graph neural networks
//!
//! A three-layer reproduction of *Heta: Distributed Training of Heterogeneous
//! Graph Neural Networks* (Zhong et al., 2024):
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator: the
//!   Relation-Aggregation-First (RAF) execution engine, meta-partitioning,
//!   the miss-penalty-aware feature cache, and the vanilla (DGL/GraphLearn
//!   style) baseline engine, together with every substrate they depend on
//!   (heterogeneous graph storage, synthetic dataset generators, samplers,
//!   a simulated cluster transport, a distributed KV store, sparse Adam).
//! * **Layer 2 (python/compile/model.py)** — the HGNN compute graphs
//!   (R-GCN, R-GAT, HGT) in JAX, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the
//!   relation-aggregation hot spot, lowered into the same HLO.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! models once, and the Rust coordinator loads and executes the artifacts
//! through the PJRT C API (`xla` crate).
//!
//! ## Worker runtimes
//!
//! Both coordinator engines run on either of two runtimes, selected by
//! the `train.runtime` config flag:
//!
//! * **sequential** (default) — one thread plays every worker in turn;
//!   epoch time is the sum of per-worker stage times (the seed
//!   behaviour, kept for A/B comparison).
//! * **cluster** ([`cluster`]) — thread-per-partition workers over a
//!   typed mailbox transport, with a leader/worker barrier and
//!   gather/scatter collectives implemented over channels, and a
//!   double-buffered minibatch pipeline that overlaps batch `i+1`'s
//!   sampling (+ read-only cache fetch, in the model) with batch `i`'s
//!   artifact execution. Collectives reduce in worker-id order, so
//!   sampled trees, losses and parameter trajectories stay
//!   byte-identical to the sequential runtime under any thread
//!   interleaving (Prop. 1 is runtime-independent).
//!
//! Both runtimes are thin schedulers over the [`exec`] layer: each
//! worker owns an [`exec::ExecContext`] (its own PJRT client, compiled
//! executables, and cache), parameters travel as versioned read-only
//! snapshots published by the leader each batch, and the per-batch
//! marshal → forward → exchange → backward → update stages are
//! expressed once in [`exec::BatchPlan`] (arenas are batch-scoped and
//! scheduler-owned). Cluster workers therefore execute artifacts
//! genuinely concurrently — no shared session, no lock around
//! execution (`train.shared_session = true` restores the old
//! serialized behavior for A/B timing) — and `train.staleness = k`
//! opens the async 1F1B window: up to `k` extra batches in flight
//! against snapshots at most `k` updates behind, with batch-tagged
//! collectives and version-pinned gradient folds keeping the schedule
//! deterministic (`k = 0` stays byte-identical to the synchronous
//! protocol).
//!
//! Both cluster engines are generic over the transport
//! ([`cluster::mailbox::Transport`]): in-process channels (the
//! default), or the socket star of [`net`] — **one OS process per
//! rank**, every cluster message crossing real TCP through the
//! versioned binary codec ([`net::codec`]), learnable-feature updates
//! replicated into worker-process stores by delta broadcast. Losses
//! are byte-identical across `transport = channel | tcp` at any fixed
//! staleness. `heta train --transport tcp --rank R --peers host:port`
//! runs one rank; `heta launch -n K` spawns and reaps a local
//! K-worker cluster.
//!
//! [`metrics::timeline`] records a per-worker event timeline either
//! way (plus wall-clock forward spans showing real context overlap);
//! [`metrics::EpochReport`] reports both the classic summed epoch
//! time and the overlap-aware critical-path time derived from it —
//! and, under the TCP transport, the real bytes on the wire next to
//! the cost model's view of the same messages.
//!
//! [`obs`] is the flight recorder over all of it: `--trace out.json`
//! arms per-rank span recording (compute / marshal / wire-wait /
//! barrier-wait attribution down to the batch and lane) in the stage
//! bodies, collectives, and TCP reader threads; workers ship their
//! clock-aligned buffers to the leader at epoch end and the merged
//! trace exports as Chrome trace-event JSON, with a metrics snapshot
//! (wire bytes per lane, per-node-type cache hit/miss, staleness
//! occupancy, grad-version lag) in [`metrics::EpochReport::obs`].
//! Tracing is zero-cost when off and passive when on — losses are
//! byte-identical either way.

pub mod util;
pub mod hetgraph;
pub mod datagen;
pub mod partition;
pub mod sampling;
pub mod comm;
pub mod kvstore;
pub mod cache;
pub mod optim;
pub mod metrics;
pub mod config;
pub mod runtime;
pub mod ckpt;
pub mod exec;
pub mod net;
pub mod obs;
pub mod cluster;
pub mod coordinator;
pub mod serve;
