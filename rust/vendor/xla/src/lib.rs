//! Offline shim for the `xla`/xla-rs crate.
//!
//! Implements the exact API surface `heta` compiles against:
//! [`Literal`] (construction, reshape, host reads, tuple decomposition),
//! [`HloModuleProto`] / [`XlaComputation`] loading, and the PJRT client
//! / executable types. Everything host-side is real; only `execute`
//! is stubbed — it returns [`Error::BackendUnavailable`], because
//! interpreting HLO requires the XLA C library this build environment
//! does not ship. Artifact-gated tests and benches detect missing
//! artifacts and skip before ever calling `execute`, so the crate keeps
//! the whole workspace buildable and testable offline.

use std::fmt;

/// Error type; the coordinator formats it with `{:?}`.
pub enum Error {
    /// `execute` called without a real PJRT backend.
    BackendUnavailable(String),
    /// Shape/dtype mismatch in a host-side literal operation.
    Literal(String),
    /// Artifact file could not be read.
    Io(String),
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BackendUnavailable(m) => write!(f, "PJRT backend unavailable: {m}"),
            Error::Literal(m) => write!(f, "literal error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the coordinator moves through literals.
mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Native element type (subset of xla-rs's `NativeType`).
pub trait NativeType: sealed::Sealed + Copy {
    fn lit_from_slice(data: &[Self]) -> Literal;
    fn vec_from_lit(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn lit_from_slice(data: &[f32]) -> Literal {
        Literal::F32 {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }
    fn vec_from_lit(lit: &Literal) -> Result<Vec<f32>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error::Literal(format!(
                "expected f32 literal, got {}",
                other.kind()
            ))),
        }
    }
}

impl NativeType for i32 {
    fn lit_from_slice(data: &[i32]) -> Literal {
        Literal::I32 {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }
    fn vec_from_lit(lit: &Literal) -> Result<Vec<i32>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(Error::Literal(format!(
                "expected i32 literal, got {}",
                other.kind()
            ))),
        }
    }
}

/// A host literal: dense f32/i32 arrays or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    fn kind(&self) -> &'static str {
        match self {
            Literal::F32 { .. } => "f32",
            Literal::I32 { .. } => "i32",
            Literal::Tuple(_) => "tuple",
        }
    }

    fn elems(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(t) => t.len(),
        }
    }

    /// Rank-1 literal from a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::lit_from_slice(data)
    }

    /// Reinterpret under new dimensions (element count must match).
    pub fn reshape(&self, new_dims: &[i64]) -> Result<Literal> {
        let n: i64 = new_dims.iter().product();
        if n < 0 || n as usize != self.elems() {
            return Err(Error::Literal(format!(
                "cannot reshape {} elements to {new_dims:?}",
                self.elems()
            )));
        }
        let mut out = self.clone();
        match &mut out {
            Literal::F32 { dims, .. } | Literal::I32 { dims, .. } => {
                *dims = new_dims.to_vec();
            }
            Literal::Tuple(_) => {
                return Err(Error::Literal("cannot reshape a tuple".to_string()))
            }
        }
        Ok(out)
    }

    /// Copy out as a flat vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::vec_from_lit(self)
    }

    /// First element (scalar reads).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::vec_from_lit(self)?
            .first()
            .copied()
            .ok_or_else(|| Error::Literal("empty literal".to_string()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(t) => Ok(t),
            other => Err(Error::Literal(format!(
                "expected tuple literal, got {}",
                other.kind()
            ))),
        }
    }
}

/// Parsed-enough HLO module: the text is retained for a real backend.
pub struct HloModuleProto {
    pub text: String,
    pub path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        Ok(HloModuleProto {
            text,
            path: path.to_string(),
        })
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation {
    pub name: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            name: proto.path.clone(),
        }
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// PJRT client handle. `cpu()` always succeeds so sessions can be
/// constructed; the missing backend surfaces at `execute` time.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {
            name: comp.name.clone(),
        })
    }
}

/// Compiled-executable handle.
pub struct PjRtLoadedExecutable {
    name: String,
}

impl PjRtLoadedExecutable {
    /// Stubbed: the shim has no HLO interpreter.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable(format!(
            "cannot execute '{}' with the vendored xla shim; link the real \
             xla-rs crate and its XLA extension library to run artifacts",
            self.name
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.reshape(&[3, 2]).is_err());
        let i = Literal::vec1(&[7i32]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7]);
        assert!(i.to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[0i32]).to_tuple().is_err());
    }

    #[test]
    fn execute_reports_missing_backend() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation {
            name: "m".to_string(),
        };
        let exe = client.compile(&comp).unwrap();
        let err = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(format!("{err:?}").contains("unavailable"));
    }
}
