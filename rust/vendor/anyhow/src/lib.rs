//! Minimal offline re-implementation of the `anyhow` 1.x API surface
//! used by this workspace: an erased error type with a context chain,
//! the [`Context`] extension trait for `Result`/`Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Behaviourally compatible for
//! the subset implemented; replace with the crates.io `anyhow` when the
//! build environment has registry access.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Erased error: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap `self` under a new context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: c.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the context chain, outermost first.
    pub fn chain<'a>(&'a self) -> impl Iterator<Item = &'a Error> + 'a {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }

    /// The root cause (innermost error in the chain).
    pub fn root_cause(&self) -> &Error {
        self.chain().last().expect("chain is non-empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain on one line.
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, e) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {}", e.msg)?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Preserve the std source chain as context entries, built
        // innermost-first.
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error {
                msg,
                source: err.map(Box::new),
            });
        }
        err.expect("chain has at least the top message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (or a single displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_chain_renders() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:?}").contains("Caused by"));
        assert!(format!("{e:#}").starts_with("reading config: "));
        assert!(e.chain().count() >= 2);
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");

        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "flag was {ok}");
            if !ok {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }
}
