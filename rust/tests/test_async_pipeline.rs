//! The async 1F1B pipeline under bounded staleness (the PR-4 tentpole).
//!
//! Artifact-free half: the stale-gradient contract of
//! [`GradAccumulator`] (a gradient tagged with the wrong snapshot
//! version is rejected, never silently mixed), and two properties over
//! the transport the window leans on — mailbox delivery never reorders
//! a worker's batch-tagged lane under arbitrary interleavings, and the
//! round-tagged collective reduction stays byte-identical to folding in
//! worker-id order for random gradient sets shipped from racing
//! threads.
//!
//! Artifact-gated half (skipped until `make artifacts`):
//! `train.staleness = 0` must be **byte-identical** across the whole
//! engine × runtime matrix (checked through the shared `tests/common`
//! harness, which reports the first diverging batch); a staleness
//! window must be deterministic run-to-run; and the extended
//! [`WallClock`] sweeps must witness the new overlap — a backward
//! running under a later batch's forward (RAF, `k = 1`) and fused
//! steps of different batches in flight together (vanilla, `k = 2`).

mod common;

use std::time::Duration;

use heta::cluster::collective::{star, RoundTag};
use heta::cluster::mailbox::Mailbox;
use heta::config::RuntimeKind;
use heta::coordinator::SystemKind;
use heta::exec::{GradAccumulator, WorkerGrads};
use heta::util::proptest;

use common::variant;

// ---- artifact-free: the stale-gradient contract ----

fn grads_with_version(v: u64) -> WorkerGrads {
    WorkerGrads {
        wgrads: vec![("w".into(), vec![1.0, -1.0])],
        param_version: v,
        ..Default::default()
    }
}

#[test]
fn grad_accumulator_version_matches_stale_snapshots() {
    // The leader pins each batch's fold to the snapshot version it
    // shipped; a worker that marshalled its backward from any other
    // snapshot (older *or* newer) is rejected with both versions named.
    let mut acc = GradAccumulator::for_version(41);
    let err = acc.absorb(grads_with_version(40)).unwrap_err().to_string();
    assert!(
        err.contains("version 40") && err.contains("version 41"),
        "rejection must name the stale and expected versions: {err}"
    );
    assert!(acc.absorb(grads_with_version(42)).is_err(), "future versions are no better");
    acc.absorb(grads_with_version(41)).unwrap();
    assert_eq!(acc.wgrads["w"], vec![1.0, -1.0]);
    // Rejected gradients must not have contaminated the fold.
    acc.absorb(grads_with_version(41)).unwrap();
    assert_eq!(acc.wgrads["w"], vec![2.0, -2.0]);
}

// ---- property: mailbox lanes never reorder ----

#[test]
fn prop_mailbox_lanes_never_reorder_under_interleaving() {
    proptest::run("mailbox_lanes", |rng, _| {
        let workers = 2 + rng.below(3);
        let batches = 1 + rng.below(4);
        // Each worker's send sequence: batch-tagged messages, several
        // per batch, in (batch, seq) order — the shape the windowed
        // runtime puts on the wire.
        let lanes: Vec<Vec<(usize, usize)>> = (0..workers)
            .map(|_| {
                let mut msgs = Vec::new();
                for bi in 0..batches {
                    for seq in 0..1 + rng.below(3) {
                        msgs.push((bi, seq));
                    }
                }
                msgs
            })
            .collect();
        // Drive the hub with one arbitrary FIFO-per-lane interleaving.
        let sched = proptest::interleave(rng, lanes.clone());
        let (hub, spokes) = Mailbox::<(usize, usize)>::star(workers);
        for (lane, msg) in sched {
            spokes[lane].send(workers, msg).map_err(|e| e.to_string())?;
        }
        let total: usize = lanes.iter().map(|l| l.len()).sum();
        let mut cursor = vec![0usize; workers];
        for _ in 0..total {
            let e = hub.recv().map_err(|e| e.to_string())?;
            let expect = lanes[e.from][cursor[e.from]];
            heta::prop_assert!(
                e.payload == expect,
                "worker {} delivered {:?} but its lane's next message is {:?} \
                 (the (worker, batch) lane reordered)",
                e.from,
                e.payload,
                expect
            );
            cursor[e.from] += 1;
        }
        Ok(())
    });
}

// ---- property: round-gathered reductions fold in worker-id order ----

#[test]
fn prop_round_gather_reduction_matches_worker_order_fold() {
    let cfg = proptest::Config {
        cases: 24,
        ..Default::default()
    };
    proptest::run_with(cfg, "round_gather_reduction", |rng, _| {
        let parts = 2 + rng.below(3);
        let dim = 4 + rng.below(12);
        let data: Vec<Vec<f32>> = (0..parts)
            .map(|_| (0..dim).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect())
            .collect();
        let delays: Vec<u64> = (0..parts).map(|_| rng.below(300) as u64).collect();

        // Reference: fold in worker-id order on one thread.
        let mut reference = GradAccumulator::for_version(1);
        for d in &data {
            let wg = WorkerGrads {
                wgrads: vec![("w".into(), d.clone())],
                row_grads: vec![(0, vec![1, 2], d[..2].to_vec())],
                param_version: 1,
                ..Default::default()
            };
            reference.absorb(wg).map_err(|e| e.to_string())?;
        }

        // Racing threads ship the same gradients in arbitrary arrival
        // order; the round gather must still hand them back in
        // worker-id order, making the fold bit-identical.
        let (mut hub, ports) = star::<WorkerGrads, ()>(parts);
        let folded: Result<GradAccumulator, String> = std::thread::scope(|s| {
            for ((port, d), delay) in ports.into_iter().zip(data.clone()).zip(delays) {
                s.spawn(move || {
                    std::thread::sleep(Duration::from_micros(delay));
                    let wg = WorkerGrads {
                        wgrads: vec![("w".into(), d.clone())],
                        row_grads: vec![(0, vec![1, 2], d[..2].to_vec())],
                        param_version: 1,
                        ..Default::default()
                    };
                    port.send(wg).unwrap();
                });
            }
            let ups = hub
                .gather_round(0, |_| RoundTag::Round(0))
                .map_err(|e| e.to_string())?;
            let mut acc = GradAccumulator::for_version(1);
            for wg in ups {
                acc.absorb(wg).map_err(|e| e.to_string())?;
            }
            Ok(acc)
        });
        let folded = folded?;
        heta::prop_assert!(
            folded.wgrads["w"] == reference.wgrads["w"],
            "dense fold diverged from worker-id-order reference"
        );
        heta::prop_assert!(
            folded.row_grads[&0] == reference.row_grads[&0],
            "row-grad concatenation diverged from worker-id-order reference"
        );
        Ok(())
    });
}

// ---- artifact-gated: the staleness matrix ----

#[test]
fn staleness_zero_is_byte_identical_across_the_matrix() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    // The window machinery (batch tags, round gathers, version pinning)
    // may not change a single bit of the synchronous protocol, on
    // either engine, with or without the pipeline.
    for system in [SystemKind::Heta, SystemKind::DglMetis] {
        common::assert_losses_identical(
            "mag-tiny",
            system,
            3,
            &[
                variant("sequential", |c| c.train.runtime = RuntimeKind::Sequential),
                variant("cluster", |c| c.train.runtime = RuntimeKind::Cluster),
                variant("cluster+no-pipeline", |c| {
                    c.train.runtime = RuntimeKind::Cluster;
                    c.train.pipeline = false;
                }),
                variant("cluster+staleness0", |c| {
                    c.train.runtime = RuntimeKind::Cluster;
                    c.train.staleness = 0;
                }),
            ],
        );
    }
}

#[test]
fn staleness_window_is_deterministic_run_to_run() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    // Bounded staleness legitimately changes the trajectory vs k = 0 —
    // but for a fixed k the schedule (releases, store barriers,
    // version-pinned folds) is deterministic, so two runs must agree
    // bit for bit.
    for (system, k) in [(SystemKind::Heta, 1), (SystemKind::DglMetis, 1), (SystemKind::Heta, 2)] {
        common::assert_losses_identical(
            "mag-tiny",
            system,
            2,
            &[
                variant("staleness-run-a", move |c| {
                    c.train.runtime = RuntimeKind::Cluster;
                    c.train.staleness = k;
                }),
                variant("staleness-run-b", move |c| {
                    c.train.runtime = RuntimeKind::Cluster;
                    c.train.staleness = k;
                }),
            ],
        );
    }
}

#[test]
fn staleness_window_overlaps_backward_with_later_forward() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    let epochs = 3;
    let k1 = common::run_reports("mag-tiny", SystemKind::Heta, epochs, "staleness1", |c| {
        c.train.runtime = RuntimeKind::Cluster;
        c.train.staleness = 1;
    });
    // The extended wall sweep: across the epochs, at least one batch's
    // backward must have genuinely run while a later batch's forward
    // was in flight — the overlap the 1F1B window exists for.
    let overlaps: usize = k1
        .iter()
        .map(|r| r.wall.backward_overlapping_later_forward())
        .sum();
    assert!(
        overlaps >= 1,
        "staleness=1 never overlapped a backward with a later forward in {epochs} epochs"
    );
    // Modeled schedule, same-run comparison (noise-free: both times
    // price the same recorded event set).
    for (ep, r) in k1.iter().enumerate() {
        assert!(
            r.critical_path_s < r.epoch_time_s,
            "epoch {ep}: async critical path {} did not beat the summed schedule {}",
            r.critical_path_s,
            r.epoch_time_s
        );
    }
    // And across runs: the window must beat the synchronous pipeline's
    // critical path (summed over epochs to damp timing noise).
    let k0 = common::run_reports("mag-tiny", SystemKind::Heta, epochs, "staleness0", |c| {
        c.train.runtime = RuntimeKind::Cluster;
    });
    let sum1: f64 = k1.iter().map(|r| r.critical_path_s).sum();
    let sum0: f64 = k0.iter().map(|r| r.critical_path_s).sum();
    assert!(
        sum1 < sum0,
        "staleness=1 critical path {sum1} not below synchronous pipeline {sum0}"
    );
}

#[test]
fn vanilla_staleness_window_overlaps_steps_across_batches() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    let epochs = 3;
    let k2 = common::run_reports("mag-tiny", SystemKind::DglMetis, epochs, "staleness2", |c| {
        c.train.runtime = RuntimeKind::Cluster;
        c.train.staleness = 2;
    });
    // The fused vanilla step has no separate backward; the window's
    // overlap evidence is fused steps of *different batches* in flight
    // together — impossible at k <= 1, where a release waits for every
    // step of the previous round.
    let overlaps: usize = k2.iter().map(|r| r.wall.cross_batch_forward_overlap()).sum();
    assert!(
        overlaps >= 1,
        "staleness=2 never ran two batches' steps concurrently in {epochs} epochs"
    );
    for (ep, r) in k2.iter().enumerate() {
        assert!(
            r.critical_path_s <= r.epoch_time_s,
            "epoch {ep}: async critical path exceeds the summed schedule"
        );
    }
}
