//! Deduplicated-frontier gather correctness (the PR-2 tentpole).
//!
//! Property tests (artifact-free): the staging-then-scatter gather must
//! produce **byte-identical** padded blocks to the seed's per-slot
//! gather on random Mag-preset graphs and samples, the frontier's
//! cached valid counts and occurrence multiplicities must agree with a
//! per-slot rescan (so `presample_hotness` counts are unchanged), and
//! the cache's batched entry point must advance hit/miss ledgers
//! exactly once per unique id per batch.
//!
//! The artifact-gated half runs full training with `dedup_fetch` on and
//! off, on both runtimes, asserting identical loss trajectories (via
//! the shared `tests/common` equivalence harness, which reports the
//! first diverging batch) and strictly fewer fetched rows — skipped
//! until `make artifacts` exists.

mod common;

use heta::cache::{FeatureCache, Policy, TypeProfile};
use heta::comm::CostModel;
use heta::config::RuntimeKind;
use heta::coordinator::SystemKind;
use heta::datagen::{generate, GenParams, Preset};
use heta::hetgraph::{MetaTree, NodeId};
use heta::kvstore::{scatter_rows, FeatureStore};
use heta::sampling::{presample_hotness, sample_tree, Frontier, PAD};
use heta::util::proptest;
use heta::util::rng::Rng;

#[test]
fn prop_dedup_gather_blocks_byte_identical() {
    proptest::run("dedup_gather_blocks", |rng, _| {
        let g = generate(
            Preset::Mag,
            1e-4,
            &GenParams { seed: rng.next_u64(), avg_degree: 6.0, ..Default::default() },
        );
        let tree = MetaTree::build(&g.schema, 2);
        let store = FeatureStore::new(&g, rng.next_u64());
        let b = 4 + rng.below(12);
        let batch: Vec<NodeId> = (0..b as u32).collect();
        let sample = sample_tree(&g, &tree, &[3, 2], &batch, 0, rng.next_u64(), |_| true);
        let fr = Frontier::build(&tree, &sample, g.schema.node_types.len(), true);

        // Stage every type's distinct rows once.
        let mut staging: Vec<Vec<f32>> = Vec::new();
        let mut unique_rows = 0u64;
        for ty in 0..g.schema.node_types.len() {
            let dim = store.dim(ty);
            let mut buf = vec![0.0f32; fr.rows(ty).len() * dim];
            let stats = store
                .gather_unique(ty, fr.rows(ty), &mut buf, |_| false)
                .map_err(|e| format!("gather_unique: {e}"))?;
            unique_rows += stats.rows;
            staging.push(buf);
        }

        // Every block literal reconstructed by scatter must match the
        // seed's direct per-slot gather bit-for-bit.
        let mut slot_rows = 0u64;
        for e in &tree.edges {
            let ty = tree.vertices[e.child].ty;
            let dim = store.dim(ty);
            let ids = &sample.ids[e.child];
            let mut direct = vec![7.0f32; ids.len() * dim];
            let stats = store
                .gather(ty, ids, &mut direct, |_| false)
                .map_err(|e| format!("gather: {e}"))?;
            slot_rows += stats.rows;
            let mut scattered = vec![3.0f32; ids.len() * dim];
            scatter_rows(&staging[ty], &fr.slot_to_unique[e.child], dim, &mut scattered);
            heta::prop_assert!(
                direct == scattered,
                "block for child {} diverged from per-slot gather",
                e.child
            );
        }
        // Root/target features scatter from the same staging.
        let tgt = g.schema.target;
        let dim = store.dim(tgt);
        let mut direct = vec![0.0f32; batch.len() * dim];
        store
            .gather(tgt, &batch, &mut direct, |_| false)
            .map_err(|e| format!("gather target: {e}"))?;
        slot_rows += batch.len() as u64;
        let mut scattered = vec![1.0f32; batch.len() * dim];
        for (i, &id) in batch.iter().enumerate() {
            let u = fr
                .unique_index(tgt, id)
                .ok_or_else(|| format!("batch id {id} missing from frontier"))?;
            scattered[i * dim..(i + 1) * dim]
                .copy_from_slice(&staging[tgt][u * dim..(u + 1) * dim]);
        }
        heta::prop_assert!(direct == scattered, "target features diverged");
        heta::prop_assert!(
            unique_rows <= slot_rows,
            "unique rows {unique_rows} exceed slot rows {slot_rows}"
        );
        Ok(())
    });
}

#[test]
fn prop_frontier_counts_match_per_slot_rescan() {
    proptest::run("frontier_counts", |rng, _| {
        let g = generate(
            Preset::Mag240m,
            5e-5,
            &GenParams { seed: rng.next_u64(), avg_degree: 4.0, ..Default::default() },
        );
        let tree = MetaTree::build(&g.schema, 2);
        let b = 4 + rng.below(12);
        let batch: Vec<NodeId> = (0..b as u32).collect();
        let sample = sample_tree(&g, &tree, &[3, 2], &batch, 0, rng.next_u64(), |_| true);
        let fr = Frontier::build(&tree, &sample, g.schema.node_types.len(), true);
        // Cached valid counts == O(slots) rescan.
        for v in 0..sample.ids.len() {
            heta::prop_assert!(
                fr.valid_counts[v] == sample.valid_count(v),
                "valid count diverged at vertex {v}"
            );
        }
        // Frontier multiplicities reproduce per-slot visit counts.
        let mut direct: Vec<std::collections::HashMap<NodeId, u32>> =
            vec![Default::default(); g.schema.node_types.len()];
        for (v, ids) in sample.ids.iter().enumerate() {
            let ty = tree.vertices[v].ty;
            for &id in ids.iter().filter(|&&id| id != PAD) {
                *direct[ty].entry(id).or_insert(0) += 1;
            }
        }
        for (ty, m) in fr.multiplicity.iter().enumerate() {
            heta::prop_assert!(
                m.len() == direct[ty].len(),
                "type {ty}: unique count diverged"
            );
            for (u, &id) in fr.rows(ty).iter().enumerate() {
                heta::prop_assert!(
                    direct[ty].get(&id) == Some(&m[u]),
                    "type {ty} id {id}: multiplicity diverged"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn presample_hotness_unchanged_by_frontier_path() {
    // Same counts as a hand-rolled per-slot rescan over the same
    // sampling schedule (the function's seed behaviour).
    let g = generate(Preset::Mag, 1e-4, &GenParams::default());
    let tree = MetaTree::build(&g.schema, 2);
    let (bsz, epochs, seed) = (16usize, 2usize, 5u64);
    let counts = presample_hotness(&g, &tree, &[4, 3], bsz, epochs, seed);

    let mut expect: Vec<Vec<u32>> = g
        .schema
        .node_types
        .iter()
        .map(|t| vec![0u32; t.count])
        .collect();
    let mut train = g.train_nodes();
    let mut rng = Rng::new(seed);
    for epoch in 0..epochs {
        rng.shuffle(&mut train);
        for (bi, chunk) in train.chunks(bsz).enumerate() {
            let s = sample_tree(&g, &tree, &[4, 3], chunk, 0, seed ^ ((epoch * 131 + bi) as u64), |_| true);
            for (v, ids) in s.ids.iter().enumerate() {
                let ty = tree.vertices[v].ty;
                for &id in ids.iter().filter(|&&id| id != PAD) {
                    expect[ty][id as usize] += 1;
                }
            }
        }
    }
    assert_eq!(counts, expect, "frontier-based hotness counts diverged");
}

#[test]
fn cache_ledgers_count_each_unique_id_once_per_batch() {
    let g = generate(Preset::Mag, 1e-4, &GenParams::default());
    let tree = MetaTree::build(&g.schema, 2);
    let batch: Vec<NodeId> = (0..16).collect();
    let sample = sample_tree(&g, &tree, &[4, 3], &batch, 0, 3, |_| true);
    let fr = Frontier::build(&tree, &sample, g.schema.node_types.len(), true);
    let profiles: Vec<TypeProfile> = g
        .schema
        .node_types
        .iter()
        .map(|t| TypeProfile {
            name: t.name.clone(),
            count: t.count,
            feat_dim: t.feat_dim,
            learnable: t.learnable,
        })
        .collect();
    let hotness = presample_hotness(&g, &tree, &[4, 3], 16, 1, 9);
    let cost = CostModel::default();
    let mut cache =
        FeatureCache::build(Policy::HotnessMissPenalty, &profiles, &hotness, &cost, 1 << 20, 1);
    for ty in 0..profiles.len() {
        cache.access_unique(&cost, ty, fr.rows(ty), 0);
        let tc = &cache.types[ty];
        assert_eq!(
            tc.hits + tc.misses,
            fr.rows(ty).len() as u64,
            "type {ty}: ledgers must advance once per unique id"
        );
    }
}

// ---- artifact-gated full-training A/B (shared harness) ----

#[test]
fn dedup_fetch_preserves_losses_and_reduces_rows_across_runtimes() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    for system in [SystemKind::Heta, SystemKind::DglOpt] {
        for runtime in [RuntimeKind::Sequential, RuntimeKind::Cluster] {
            let reports = common::assert_losses_identical(
                "mag-tiny",
                system,
                2,
                &[
                    common::variant("dedup-on", move |c| c.train.runtime = runtime),
                    common::variant("dedup-off", move |c| {
                        c.train.runtime = runtime;
                        c.train.dedup_fetch = false;
                    }),
                ],
            );
            for (ep, (on, off)) in reports[0].iter().zip(&reports[1]).enumerate() {
                assert!(
                    on.fetch.rows < off.fetch.rows && on.fetch.bytes < off.fetch.bytes,
                    "{system:?}/{runtime:?} epoch {ep}: rows {} !< {} or bytes {} !< {}",
                    on.fetch.rows,
                    off.fetch.rows,
                    on.fetch.bytes,
                    off.fetch.bytes
                );
            }
        }
    }
}
