//! Shared equivalence-test harness (PR 4).
//!
//! Three integration tests used to copy-paste the same artifact-gated
//! scaffolding: load `configs/<name>.json`, tweak one training flag,
//! run a few epochs on some engine, and assert the loss trajectory is
//! **byte-identical** to a reference run. That scaffolding now lives
//! here once: [`assert_losses_identical`] runs a whole config matrix
//! (each [`Variant`] is one tweak of the base config) and, on
//! divergence, reports the **first diverging batch index** — far more
//! actionable than an epoch-mean mismatch, since the batch index
//! localizes which release/update of the protocol first went wrong.
//!
//! The harness is deliberately strict: equality is bitwise (`==` on
//! `f64`), never approximate. The whole point of the determinism
//! contract (reductions fold in worker-id order; snapshots are
//! versioned; store phases are disjoint) is that "equivalent" means
//! *equal*.

#![allow(dead_code)] // each test binary uses a subset of the harness

use heta::config::Config;
use heta::coordinator::{
    run_loopback_tcp, run_loopback_tcp_recovering, Engine, Session, SystemKind,
};
use heta::metrics::EpochReport;

/// How a variant's epochs execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Runner {
    /// In this process: one `Session`, threads (or the sequential
    /// driver) over in-process channels.
    #[default]
    InProcess,
    /// A loopback-TCP star (`heta::coordinator::run_loopback_tcp`):
    /// one thread **and one Session** per rank — separate feature and
    /// parameter stores, every message through the socket codec. The
    /// process-per-rank semantics of `heta launch`, minus the
    /// subprocess management.
    LoopbackTcp,
    /// [`LoopbackTcp`](Runner::LoopbackTcp) under the kill-and-recover
    /// supervisor (`heta::coordinator::run_loopback_tcp_recovering`):
    /// epoch-boundary checkpoints to a per-variant temp dir, and when
    /// the config's injected fault (`train.fail`) kills the cluster,
    /// it is relaunched with the fault cleared, resuming from the
    /// checkpoint. The concatenated reports must still be the full
    /// `epochs`-long trajectory — that is the recovery contract.
    ChaosTcp,
}

/// One cell of an equivalence matrix: a label for failure messages, a
/// tweak applied to the freshly loaded base config, and the runner the
/// variant executes on.
pub struct Variant {
    pub label: String,
    pub tweak: Box<dyn Fn(&mut Config)>,
    pub runner: Runner,
}

/// Shorthand constructor so matrices read as data.
pub fn variant(label: &str, tweak: impl Fn(&mut Config) + 'static) -> Variant {
    Variant {
        label: label.to_string(),
        tweak: Box::new(tweak),
        runner: Runner::InProcess,
    }
}

/// A variant that runs over the loopback-TCP star (cluster runtime
/// implied; the tweak still applies staleness etc.).
pub fn variant_tcp(label: &str, tweak: impl Fn(&mut Config) + 'static) -> Variant {
    Variant {
        label: label.to_string(),
        tweak: Box::new(tweak),
        runner: Runner::LoopbackTcp,
    }
}

/// A variant that runs the loopback-TCP star under checkpointed
/// kill-and-recover supervision; the tweak usually sets `train.fail`.
pub fn variant_chaos(label: &str, tweak: impl Fn(&mut Config) + 'static) -> Variant {
    Variant {
        label: label.to_string(),
        tweak: Box::new(tweak),
        runner: Runner::ChaosTcp,
    }
}

/// Load `configs/<cfg_name>.json`, apply `tweak`, build the engine for
/// `system` over `artifacts/<cfg_name>` and run `epochs` epochs on the
/// given runner. Panics (with the variant context) on any error —
/// harness callers have already passed the artifact gate.
pub fn run_reports_on(
    cfg_name: &str,
    system: SystemKind,
    epochs: usize,
    label: &str,
    tweak: impl Fn(&mut Config),
    runner: Runner,
) -> Vec<EpochReport> {
    let mut cfg = Config::load(&format!("configs/{cfg_name}.json"))
        .unwrap_or_else(|e| panic!("[{label}] loading config {cfg_name}: {e}"));
    tweak(&mut cfg);
    let dir = format!("artifacts/{cfg_name}");
    match runner {
        Runner::InProcess => {
            let mut sess = Session::new(&cfg, &dir)
                .unwrap_or_else(|e| panic!("[{label}] session for {cfg_name}: {e}"));
            let mut engine = Engine::build(&mut sess, system)
                .unwrap_or_else(|e| panic!("[{label}] building {system:?}: {e}"));
            (0..epochs)
                .map(|ep| {
                    engine
                        .run_epoch(&mut sess, ep)
                        .unwrap_or_else(|e| panic!("[{label}] {system:?} epoch {ep}: {e:#}"))
                })
                .collect()
        }
        Runner::LoopbackTcp => {
            cfg.train.runtime = heta::config::RuntimeKind::Cluster;
            cfg.train.transport = heta::config::TransportKind::Tcp;
            run_loopback_tcp(&cfg, &dir, system, epochs)
                .unwrap_or_else(|e| panic!("[{label}] {system:?} loopback tcp: {e:#}"))
        }
        Runner::ChaosTcp => {
            cfg.train.runtime = heta::config::RuntimeKind::Cluster;
            cfg.train.transport = heta::config::TransportKind::Tcp;
            // A private checkpoint dir per variant, wiped up front: a
            // stale checkpoint from an earlier run would make the
            // cluster skip epochs instead of training them.
            let slug: String = label
                .chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
                .collect();
            let ckpt_dir = std::env::temp_dir()
                .join(format!("heta-chaos-{slug}-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&ckpt_dir);
            let ckpt_dir = ckpt_dir.to_string_lossy().into_owned();
            let reports = run_loopback_tcp_recovering(&cfg, &dir, system, epochs, &ckpt_dir, 3)
                .unwrap_or_else(|e| panic!("[{label}] {system:?} chaos tcp: {e:#}"));
            assert_eq!(
                reports.len(),
                epochs,
                "[{label}] {system:?} chaos tcp: recovery produced {} epoch reports, \
                 expected {epochs} (an epoch was lost or duplicated across the restart)",
                reports.len(),
            );
            reports
        }
    }
}

/// [`run_reports_on`] with the in-process runner (the pre-PR-5 shape,
/// kept for the callers that never cross a transport).
pub fn run_reports(
    cfg_name: &str,
    system: SystemKind,
    epochs: usize,
    label: &str,
    tweak: impl Fn(&mut Config),
) -> Vec<EpochReport> {
    run_reports_on(cfg_name, system, epochs, label, tweak, Runner::InProcess)
}

/// Run every variant of the matrix and assert all of them produce
/// trajectories bitwise-identical to the first (the reference):
/// per-batch losses, epoch loss means and accuracies. On divergence,
/// panics naming the variant and the **first diverging batch** (epoch,
/// batch index, both values). Returns every variant's reports, in
/// matrix order, for follow-up assertions (timing, fetch stats, ...).
pub fn assert_losses_identical(
    cfg_name: &str,
    system: SystemKind,
    epochs: usize,
    matrix: &[Variant],
) -> Vec<Vec<EpochReport>> {
    assert!(matrix.len() >= 2, "an equivalence matrix needs a reference and a candidate");
    let all: Vec<Vec<EpochReport>> = matrix
        .iter()
        .map(|v| run_reports_on(cfg_name, system, epochs, &v.label, &v.tweak, v.runner))
        .collect();
    let (reference, candidates) = all.split_first().expect("non-empty matrix");
    let ref_label = &matrix[0].label;
    for (v, reps) in matrix[1..].iter().zip(candidates) {
        for (ep, (r, c)) in reference.iter().zip(reps).enumerate() {
            assert_eq!(
                r.batch_losses.len(),
                c.batch_losses.len(),
                "{system:?} [{}] epoch {ep}: ran {} batches but reference [{ref_label}] ran {}",
                v.label,
                c.batch_losses.len(),
                r.batch_losses.len(),
            );
            if let Some(bi) = (0..r.batch_losses.len())
                .find(|&i| r.batch_losses[i].to_bits() != c.batch_losses[i].to_bits())
            {
                panic!(
                    "{system:?} [{}] diverged from [{ref_label}] first at epoch {ep} batch {bi}: \
                     {} != {} (losses must be byte-identical)",
                    v.label, c.batch_losses[bi], r.batch_losses[bi],
                );
            }
            assert_eq!(
                r.loss_mean, c.loss_mean,
                "{system:?} [{}] epoch {ep}: loss mean diverged from [{ref_label}] \
                 with equal per-batch losses (aggregation bug)",
                v.label,
            );
            assert_eq!(
                r.accuracy, c.accuracy,
                "{system:?} [{}] epoch {ep}: accuracy diverged from [{ref_label}]",
                v.label,
            );
        }
    }
    all
}
