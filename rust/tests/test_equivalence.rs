//! Integration: Proposition 1 end-to-end. The RAF engine (model-parallel
//! partial aggregations exchanged between partitions) and the vanilla
//! engine (data-parallel full-tree computation over edge-cut partitions)
//! must produce the same losses, accuracies and parameter trajectories —
//! through real AOT-compiled PJRT executables, multiple training steps,
//! and sparse learnable-feature updates.

use heta::config::Config;
use heta::coordinator::{Engine, Session, SystemKind};

fn run(system: SystemKind, cfg_name: &str, epochs: usize) -> Vec<(f64, f64)> {
    let cfg = Config::load(&format!("configs/{cfg_name}.json")).unwrap();
    let dir = format!("artifacts/{cfg_name}");
    let mut sess = Session::new(&cfg, &dir).unwrap();
    let mut engine = Engine::build(&mut sess, system).unwrap();
    (0..epochs)
        .map(|ep| {
            let r = engine.run_epoch(&mut sess, ep).unwrap();
            (r.loss_mean, r.accuracy)
        })
        .collect()
}

#[test]
fn raf_equals_vanilla_over_training() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    let raf = run(SystemKind::Heta, "mag-tiny", 3);
    let van = run(SystemKind::DglMetis, "mag-tiny", 3);
    for (ep, ((lr, ar), (lv, av))) in raf.iter().zip(&van).enumerate() {
        assert!(
            (lr - lv).abs() < 1e-3 * lr.abs().max(1.0),
            "epoch {ep}: RAF loss {lr} != vanilla loss {lv}"
        );
        assert!((ar - av).abs() < 1e-6, "epoch {ep}: acc {ar} vs {av}");
    }
}

#[test]
fn raf_equals_vanilla_rgat() {
    if !heta::util::artifacts_ready("mag-tiny-rgat") {
        return;
    }
    let raf = run(SystemKind::Heta, "mag-tiny-rgat", 2);
    let van = run(SystemKind::DglRandom, "mag-tiny-rgat", 2);
    for (ep, ((lr, _), (lv, _))) in raf.iter().zip(&van).enumerate() {
        assert!(
            (lr - lv).abs() < 1e-3 * lr.abs().max(1.0),
            "epoch {ep}: RAF {lr} vs vanilla {lv}"
        );
    }
}

#[test]
fn raf_equals_vanilla_hgt() {
    if !heta::util::artifacts_ready("mag-tiny-hgt") {
        return;
    }
    let raf = run(SystemKind::Heta, "mag-tiny-hgt", 2);
    let van = run(SystemKind::GraphLearn, "mag-tiny-hgt", 2);
    for (ep, ((lr, _), (lv, _))) in raf.iter().zip(&van).enumerate() {
        assert!(
            (lr - lv).abs() < 1e-3 * lr.abs().max(1.0),
            "epoch {ep}: RAF {lr} vs vanilla {lv}"
        );
    }
}

#[test]
fn training_reduces_loss() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    let curve = run(SystemKind::Heta, "mag-tiny", 6);
    let first = curve.first().unwrap().0;
    let last = curve.last().unwrap().0;
    assert!(
        last < first - 0.2,
        "loss did not decrease: {first} -> {last} ({curve:?})"
    );
}

#[test]
fn raf_communicates_less_than_vanilla() {
    // Props. 2–3 in effect: per-epoch network bytes under RAF must be
    // well below the vanilla engine's feature-fetch traffic.
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    let cfg = Config::load("configs/mag-tiny.json").unwrap();
    let mut s1 = Session::new(&cfg, "artifacts/mag-tiny").unwrap();
    let mut e1 = Engine::build(&mut s1, SystemKind::Heta).unwrap();
    let r1 = e1.run_epoch(&mut s1, 0).unwrap();
    let mut s2 = Session::new(&cfg, "artifacts/mag-tiny").unwrap();
    let mut e2 = Engine::build(&mut s2, SystemKind::DglRandom).unwrap();
    let r2 = e2.run_epoch(&mut s2, 0).unwrap();
    let raf_net = r1.comm.bytes[0];
    let van_net = r2.comm.bytes[0];
    assert!(
        raf_net * 3 < van_net,
        "expected >3x comm reduction: raf {raf_net} vs vanilla {van_net}"
    );
}
