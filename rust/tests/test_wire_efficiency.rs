//! The wire-efficiency tier (the PR-8 tentpole).
//!
//! Artifact-free half: property tests of the delta-snapshot chain —
//! a leader-side [`DiffChain`] and a worker-side [`SnapshotChain`]
//! driven by random optimizer steps must reconstruct the exact
//! snapshot trajectory the full-snapshot protocol would have shipped;
//! chain gaps are `anyhow` errors naming the versions (never panics);
//! diff frames price and carry only the tensors that advanced; and
//! [`ParamDiff`] survives the socket codec bitwise.
//!
//! Artifact-gated half (skipped until `make artifacts`): the
//! equivalence bar across the PR-8 wire knobs — `wire_snapshots ∈
//! {full, diff}` × `wire_exchange ∈ {star, mesh}` must produce
//! **byte-identical** per-batch losses across `transport = channel |
//! tcp`, both engines, staleness 0 and 1. Plus the byte-win
//! assertions: the diff run's leader ships fewer real bytes than the
//! full run, and the mesh run's leader receives fewer than the star
//! run (the partial aggregation moved to the worker↔worker lane).
//! Finally the ChaosTcp variant: a rank killed mid-epoch under diff
//! mode recovers through the full-resync path (the restarted epoch's
//! first frame is full) with the trajectory still byte-identical.

mod common;

use std::sync::Arc;

use heta::config::{FaultSpec, WireExchange, WireSnapshots};
use heta::coordinator::SystemKind;
use heta::net::codec::{decode_message, encode_message};
use heta::optim::AdamParams;
use heta::runtime::{DiffChain, InputSpec, ParamDiff, ParamStore, SnapOrDiff, SnapshotChain};
use heta::util::proptest;
use heta::util::rng::Rng;

use common::{variant, variant_chaos, variant_tcp};

// ---- artifact-free: the diff chain ----

/// A toy parameter store with `n` small dense tensors.
fn toy_store(seed: u64, n: usize) -> ParamStore {
    let mut store = ParamStore::new(seed, AdamParams::default());
    for i in 0..n {
        store.ensure(&InputSpec {
            kind: "weight".to_string(),
            shape: vec![2, 3],
            name: format!("w{i}"),
            edge: -1,
            layer: 0,
            dtype: "f32".to_string(),
            init: "glorot".to_string(),
        });
    }
    store
}

/// Random Adam steps on a random subset of tensors; each step bumps
/// the store version, so diffs ship a genuine subset per batch.
fn random_steps(rng: &mut Rng, store: &mut ParamStore, n: usize) {
    for _ in 0..rng.below(3) {
        let name = format!("w{}", rng.below(n));
        let grad: Vec<f32> = (0..6).map(|_| rng.f32() - 0.5).collect();
        store.step(&name, &grad).expect("step on a known tensor");
    }
}

/// Bitwise equality of two snapshots' tensors (and versions).
fn snaps_equal(a: &heta::runtime::ParamSnapshot, b: &heta::runtime::ParamSnapshot) -> bool {
    a.version == b.version
        && a.tensors_sorted()
            .iter()
            .zip(b.tensors_sorted())
            .all(|((an, ad), (bn, bd))| *an == bn && ad.len() == bd.len() && {
                ad.iter().zip(bd).all(|(x, y)| x.to_bits() == y.to_bits())
            })
        && a.len() == b.len()
}

#[test]
fn prop_diff_chain_reconstructs_the_snapshot_trajectory() {
    proptest::run("wire_diff_chain", |rng, _| {
        let n = 1 + rng.below(4);
        let mut store = toy_store(rng.next_u64(), n);
        let mut leader = DiffChain::new(true);
        let mut worker = SnapshotChain::new();
        for release in 0..4 + rng.below(8) {
            random_steps(rng, &mut store, n);
            let want = store.snapshot();
            let got = match leader.next(&store) {
                SnapOrDiff::Full(snap) => {
                    heta::prop_assert!(
                        release == 0,
                        "an unbroken diff chain must go full only on its first frame \
                         (went full again at release {release})"
                    );
                    worker.note_full(&snap);
                    snap
                }
                SnapOrDiff::Diff(diff) => {
                    heta::prop_assert!(
                        diff.to_version == store.version(),
                        "diff must advance to the store version: {} != {}",
                        diff.to_version,
                        store.version()
                    );
                    worker
                        .apply(0, &diff)
                        .map_err(|e| format!("release {release}: chain apply failed: {e:#}"))?
                }
            };
            heta::prop_assert!(
                snaps_equal(&got, &want),
                "release {release}: the worker's overlaid snapshot diverged from the \
                 store (v{} vs v{})",
                got.version,
                want.version
            );
            heta::prop_assert!(
                worker.version() == Some(store.version()),
                "worker chain cursor must track the store version"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_diff_chain_gap_is_an_error_never_a_panic() {
    proptest::run("wire_diff_gap", |rng, _| {
        let n = 1 + rng.below(3);
        let mut store = toy_store(rng.next_u64(), n);
        let mut leader = DiffChain::new(true);
        let mut worker = SnapshotChain::new();
        // Prime the chain with the first (full) frame.
        match leader.next(&store) {
            SnapOrDiff::Full(snap) => worker.note_full(&snap),
            SnapOrDiff::Diff(_) => return Err("first frame must be full".to_string()),
        }
        // A diff whose base the worker never saw: guaranteed gap, since
        // the lost frame's steps advanced the leader cursor.
        store.step("w0", &[0.1; 6]).expect("step");
        let lost = leader.next(&store); // dropped on the floor
        drop(lost);
        store.step("w0", &[0.2; 6]).expect("step");
        if let SnapOrDiff::Diff(diff) = leader.next(&store) {
            let err = worker
                .apply(3, &diff)
                .expect_err("a version gap must be an error, not a silent overlay");
            let text = format!("{err:#}");
            heta::prop_assert!(
                text.contains(&format!("v{}", diff.from_version)),
                "the gap error must name the missing base version: {text}"
            );
        } else {
            return Err("a primed chain must emit diffs".to_string());
        }
        // A diff landing on a worker that holds no snapshot at all is
        // the NeedFull case — also an error, also named.
        let mut fresh = SnapshotChain::new();
        let diff = store.diff_since(store.version()); // empty but versioned
        if diff.from_version > 0 {
            let err = fresh
                .apply(1, &diff)
                .expect_err("a chain with no base must demand a full snapshot");
            heta::prop_assert!(
                !format!("{err:#}").is_empty(),
                "the no-base error must describe itself"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_param_diffs_round_trip_bitwise() {
    proptest::run("wire_diff_codec", |rng, _| {
        let tensors: Vec<(String, Vec<f32>)> = (0..rng.below(5))
            .map(|i| {
                let data: Vec<f32> = (0..rng.below(32)).map(|_| rng.f32() * 4.0 - 2.0).collect();
                (format!("t{i}"), data)
            })
            .collect();
        let from = rng.next_u64() >> 1;
        let diff = ParamDiff::from_tensors(from, from + rng.below(9) as u64, tensors);
        let back: ParamDiff =
            decode_message(&encode_message(&diff)).map_err(|e| format!("decode: {e:#}"))?;
        heta::prop_assert!(back == diff, "diff changed in flight: {diff:?} -> {back:?}");
        Ok(())
    });
}

#[test]
fn diff_frames_ship_only_advanced_tensors() {
    let mut store = toy_store(7, 3);
    let base = store.version();
    store.step("w1", &[0.5; 6]).expect("step");
    let diff = store.diff_since(base);
    assert_eq!(diff.len(), 1, "only the stepped tensor advanced");
    assert_eq!(diff.tensors_sorted()[0].0, "w1");
    assert_eq!((diff.from_version, diff.to_version), (base, store.version()));
    // The byte win, at frame level: the encoded diff is strictly
    // smaller than the encoded full snapshot it replaces.
    let full = store.snapshot();
    let diff_bytes = encode_message(&diff).len();
    let full_bytes = encode_message(&full).len();
    assert!(
        diff_bytes < full_bytes,
        "a 1-of-3-tensor diff must beat the full snapshot: {diff_bytes} >= {full_bytes}"
    );
    // An idle release diffs to an empty frame — the O(1) floor.
    let idle = store.diff_since(store.version());
    assert!(idle.is_empty(), "no steps, no tensors");
    assert_eq!(idle.total_elems(), 0);
}

#[test]
fn disabled_diff_chain_always_goes_full() {
    let mut store = toy_store(11, 2);
    let mut chain = DiffChain::new(false);
    for _ in 0..3 {
        store.step("w0", &[0.25; 6]).expect("step");
        match chain.next(&store) {
            SnapOrDiff::Full(snap) => assert_eq!(snap.version, store.version()),
            SnapOrDiff::Diff(d) => panic!(
                "wire_snapshots = full must never emit a diff (got v{}..v{})",
                d.from_version, d.to_version
            ),
        }
    }
}

#[test]
fn chain_reset_after_recovery_restart_is_the_resync() {
    // The recovery contract: an epoch restart builds fresh chains on
    // both sides, so the first post-restart frame is full no matter
    // where the old chain's cursor was — the NeedFull NACK and the
    // restart path converge on the same resync.
    let mut store = toy_store(13, 2);
    let mut leader = DiffChain::new(true);
    let _ = leader.next(&store);
    store.step("w0", &[0.5; 6]).expect("step");
    let _ = leader.next(&store); // cursor now past v0
    // "Restart": new chains, same (restored) store.
    let mut leader = DiffChain::new(true);
    let mut worker = SnapshotChain::new();
    match leader.next(&store) {
        SnapOrDiff::Full(snap) => {
            assert_eq!(snap.version, store.version());
            worker.note_full(&snap);
            assert_eq!(worker.version(), Some(store.version()));
        }
        SnapOrDiff::Diff(_) => panic!("a fresh chain's first frame must be full"),
    }
    let arc_check: Arc<heta::runtime::ParamSnapshot> = Arc::new(store.snapshot());
    assert_eq!(arc_check.version, store.version());
}

// ---- artifact-gated: the wire-knob equivalence matrix ----

const CFG: &str = "mag-tiny";
const EPOCHS: usize = 2;

fn wire(c: &mut heta::config::Config, snaps: WireSnapshots, exch: WireExchange) {
    c.train.runtime = heta::config::RuntimeKind::Cluster;
    c.train.wire_snapshots = snaps;
    c.train.wire_exchange = exch;
}

#[test]
fn losses_byte_identical_across_wire_knobs_raf() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    let reports = common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant("channel/diff/star/k0", |c| wire(c, WireSnapshots::Diff, WireExchange::Star)),
            variant("channel/full/star/k0", |c| wire(c, WireSnapshots::Full, WireExchange::Star)),
            variant("channel/diff/mesh/k0", |c| wire(c, WireSnapshots::Diff, WireExchange::Mesh)),
            variant_tcp("tcp/full/star/k0", |c| wire(c, WireSnapshots::Full, WireExchange::Star)),
            variant_tcp("tcp/diff/star/k0", |c| wire(c, WireSnapshots::Diff, WireExchange::Star)),
            variant_tcp("tcp/diff/mesh/k0", |c| wire(c, WireSnapshots::Diff, WireExchange::Mesh)),
            variant_tcp("tcp/full/mesh/k0", |c| wire(c, WireSnapshots::Full, WireExchange::Mesh)),
        ],
    );
    // The byte-win bars, on the leader's counted traffic (reports 3..
    // are the tcp runs, matrix order above).
    let sent = |i: usize| reports[i].iter().map(|r| r.wire.real_sent).sum::<u64>();
    let recv = |i: usize| reports[i].iter().map(|r| r.wire.real_recv).sum::<u64>();
    assert!(
        sent(4) < sent(3),
        "diff snapshots must shrink the leader's broadcast bytes: diff {} >= full {}",
        sent(4),
        sent(3)
    );
    assert!(
        recv(5) < recv(4),
        "the mesh must shrink the leader's gather bytes: mesh {} >= star {}",
        recv(5),
        recv(4)
    );
    // The leader never holds mesh sockets — its own mesh counters stay
    // zero even in mesh runs; the split lives in the workers' reports.
    for rep in reports[5].iter().chain(&reports[6]) {
        assert_eq!(rep.wire.mesh_sent, 0, "the leader must not send on the mesh lane");
    }
}

#[test]
fn losses_byte_identical_across_wire_knobs_raf_staleness_1() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant("channel/diff/star/k1", |c| {
                wire(c, WireSnapshots::Diff, WireExchange::Star);
                c.train.staleness = 1;
            }),
            variant_tcp("tcp/full/star/k1", |c| {
                wire(c, WireSnapshots::Full, WireExchange::Star);
                c.train.staleness = 1;
            }),
            variant_tcp("tcp/diff/star/k1", |c| {
                wire(c, WireSnapshots::Diff, WireExchange::Star);
                c.train.staleness = 1;
            }),
            variant_tcp("tcp/diff/mesh/k1", |c| {
                wire(c, WireSnapshots::Diff, WireExchange::Mesh);
                c.train.staleness = 1;
            }),
        ],
    );
}

/// The vanilla engine has no partial-aggregation exchange, so the mesh
/// knob is a documented no-op there — but a mesh-dialed cluster still
/// runs the brokered handshake, which must not disturb the protocol.
#[test]
fn losses_byte_identical_across_wire_knobs_vanilla() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    let reports = common::assert_losses_identical(
        CFG,
        SystemKind::DglMetis,
        EPOCHS,
        &[
            variant("channel/diff/star/k0", |c| wire(c, WireSnapshots::Diff, WireExchange::Star)),
            variant("channel/full/star/k0", |c| wire(c, WireSnapshots::Full, WireExchange::Star)),
            variant_tcp("tcp/full/star/k0", |c| wire(c, WireSnapshots::Full, WireExchange::Star)),
            variant_tcp("tcp/diff/star/k0", |c| wire(c, WireSnapshots::Diff, WireExchange::Star)),
            variant_tcp("tcp/diff/mesh/k0", |c| wire(c, WireSnapshots::Diff, WireExchange::Mesh)),
        ],
    );
    let sent = |i: usize| reports[i].iter().map(|r| r.wire.real_sent).sum::<u64>();
    assert!(
        sent(3) < sent(2),
        "diff snapshots must shrink the vanilla leader's bytes too: diff {} >= full {}",
        sent(3),
        sent(2)
    );
}

#[test]
fn losses_byte_identical_across_wire_knobs_vanilla_staleness_1() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::DglMetis,
        EPOCHS,
        &[
            variant("channel/diff/star/k1", |c| {
                wire(c, WireSnapshots::Diff, WireExchange::Star);
                c.train.staleness = 1;
            }),
            variant_tcp("tcp/full/star/k1", |c| {
                wire(c, WireSnapshots::Full, WireExchange::Star);
                c.train.staleness = 1;
            }),
            variant_tcp("tcp/diff/mesh/k1", |c| {
                wire(c, WireSnapshots::Diff, WireExchange::Mesh);
                c.train.staleness = 1;
            }),
        ],
    );
}

// ---- artifact-gated: recovery resyncs the diff chain ----

/// The fault fires in epoch 1, so attempt one completes epoch 0 and
/// checkpoints; the restarted epoch rebuilds both chains — its first
/// frame is a full snapshot against the *restored* store, which is
/// exactly the resync protocol. The trajectory must not notice.
#[test]
fn recovery_resyncs_the_diff_chain_byte_identical() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant_tcp("tcp/diff/fault-free/k0", |c| {
                wire(c, WireSnapshots::Diff, WireExchange::Star)
            }),
            variant_chaos("tcp/diff/kill-rank1/k0", |c| {
                wire(c, WireSnapshots::Diff, WireExchange::Star);
                c.train.fail = Some(FaultSpec::parse("1:2:exit:1").unwrap());
            }),
        ],
    );
}
