//! Integration property tests for the paper's partitioning propositions
//! (Props. 2–3) and meta-partitioning invariants (§5), across datasets,
//! partition counts and seeds.

use heta::datagen::{generate, GenParams, Preset};
use heta::partition::{edgecut, meta::meta_partition, metis_like, quality};
use heta::util::proptest;

#[test]
fn prop3_max_boundary_le_cut_all_partitioners() {
    proptest::run_with(
        proptest::Config { cases: 24, seed: 0x1234 },
        "prop3_all",
        |rng, _| {
            let preset = [Preset::Mag, Preset::Donor, Preset::Mag240m][rng.below(3)];
            let g = generate(
                preset,
                6e-5,
                &GenParams { seed: rng.next_u64(), ..Default::default() },
            );
            let k = 2 + rng.below(3);
            let p = match rng.below(3) {
                0 => edgecut::random(&g, k, rng.next_u64()),
                1 => edgecut::by_type(&g, k, rng.next_u64()),
                _ => metis_like::metis_like(&g, k, rng.next_u64()),
            };
            let cut = quality::edge_cut(&g, &p);
            let bounds = quality::boundary_nodes(&g, &p);
            heta::prop_assert!(
                *bounds.iter().max().unwrap() <= cut.max(1),
                "max|B|={} > cut={} ({})",
                bounds.iter().max().unwrap(),
                cut,
                p.method
            );
            Ok(())
        },
    );
}

#[test]
fn meta_partition_boundary_constant_in_fanout() {
    // The §8.6 scalability/sampling claim: meta-partitioning's boundary
    // set (= target nodes) does not grow with partitions or fanout.
    let g = generate(Preset::Mag, 2e-4, &GenParams::default());
    let targets = g.schema.node_types[g.schema.target].count as u64;
    for parts in [2, 3, 4] {
        let (mp, _) = meta_partition(&g, parts, 2, None);
        let b = quality::meta_boundary_nodes(&g, &mp);
        assert!(b.iter().all(|&x| x <= targets));
    }
}

#[test]
fn meta_partition_faster_than_metis_like() {
    // Table 2's time ordering at equal input size.
    let g = generate(Preset::Mag, 1e-3, &GenParams::default());
    let t0 = std::time::Instant::now();
    let (_, _) = meta_partition(&g, 2, 2, None);
    let meta_t = t0.elapsed().as_secs_f64();
    let p = metis_like::metis_like(&g, 2, 1);
    assert!(
        meta_t < p.elapsed_s,
        "meta {meta_t}s should beat metis-like {}s",
        p.elapsed_s
    );
}

#[test]
fn meta_partition_memory_below_edge_cut_methods() {
    // Table 2's peak-memory ordering.
    let g = generate(Preset::Mag, 5e-4, &GenParams::default());
    let (mp, _) = meta_partition(&g, 2, 2, None);
    let r = edgecut::random(&g, 2, 1);
    let m = metis_like::metis_like(&g, 2, 1);
    assert!(mp.peak_mem_bytes < r.peak_mem_bytes / 10);
    assert!(mp.peak_mem_bytes < m.peak_mem_bytes / 10);
}

#[test]
fn partition_cover_is_exact() {
    proptest::run_with(
        proptest::Config { cases: 16, seed: 0x777 },
        "meta_cover",
        |rng, _| {
            let g = generate(
                Preset::Donor,
                8e-5,
                &GenParams { seed: rng.next_u64(), ..Default::default() },
            );
            let parts = 2 + rng.below(4);
            let (mp, tree) = meta_partition(&g, parts, 2, None);
            // Every tree-reachable relation is in ≥1 partition; no
            // partition holds duplicates.
            let mut reach: Vec<usize> = tree.edges.iter().map(|e| e.rel).collect();
            reach.sort();
            reach.dedup();
            for r in reach {
                heta::prop_assert!(
                    mp.rels_per_part.iter().any(|rs| rs.contains(&r)),
                    "relation {r} uncovered"
                );
            }
            for rs in &mp.rels_per_part {
                let mut d = rs.clone();
                d.dedup();
                heta::prop_assert!(d.len() == rs.len(), "duplicate relations in partition");
            }
            Ok(())
        },
    );
}
