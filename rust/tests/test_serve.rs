//! Serving-mode correctness (the PR-9 tentpole).
//!
//! Property tests (artifact-free): the deadline-driven batcher misses
//! zero deadlines whenever capacity never binds, per-batch service
//! stays within the declared bound, and every budget covers two bounds
//! (one batch's close-wait plus its service) — over random synthetic
//! streams; and batches never exceed capacity under any load.
//!
//! The artifact-gated half (skipped until `make artifacts`) pins the
//! serving invariant from `docs/SERVING.md`: a served embedding is
//! **byte-identical** to a fresh forward of the same target —
//! independent of microbatch composition (splice sampling), of the
//! engine label (Heta vs the vanilla baseline share the forward-only
//! decomposition), of the transport (channel vs loopback TCP), and of
//! cache history (second runs serve from cache; a parameter-version
//! bump or a store update invalidates and recomputes to the same
//! bytes).

use std::sync::Arc;
use std::time::Instant;

use heta::config::{partition_edge_filter, Config};
use heta::coordinator::{Session, SystemKind};
use heta::datagen::{generate, GenParams, Preset};
use heta::exec::{BatchArena, BatchPlan, EpochWorld, ExecContext, ParamsView};
use heta::net::Backend;
use heta::partition::meta::meta_partition;
use heta::sampling::{sample_tree, PAD};
use heta::serve::{
    batcher, build_stream, run_loopback_tcp_serve, run_serve, serve_seed, synthetic_stream,
    BatcherOpts, ServeEngine, ServeOpts, StreamOpts,
};
use heta::util::{artifacts_ready, proptest};

const CFG: &str = "configs/mag-tiny.json";
const DIR: &str = "artifacts/mag-tiny";

fn load_cfg() -> Config {
    Config::load(CFG).unwrap_or_else(|e| panic!("loading {CFG}: {e}"))
}

/// Fast-drain opts: offered load high enough that batches fill, few
/// enough requests that every test stays sub-second per forward set.
fn quick_opts() -> ServeOpts {
    ServeOpts {
        requests: 24,
        qps: 2000.0,
        deadline_ms: 200.0,
        ..Default::default()
    }
}

#[test]
fn prop_no_deadline_misses_when_budget_covers_two_bounds() {
    proptest::run("serve_deadline_budget", |rng, _| {
        let g = generate(
            Preset::Mag,
            1e-4,
            &GenParams { seed: rng.next_u64(), ..Default::default() },
        );
        let deadline_ms = 2.0 + rng.f64() * 80.0;
        let reqs = synthetic_stream(
            &g,
            &StreamOpts {
                requests: 20 + rng.below(120),
                qps: 50.0 + rng.f64() * 5000.0,
                deadline_ms,
                zipf_alpha: 0.8 + rng.f64(),
                seed: rng.next_u64(),
            },
        )
        .map_err(|e| format!("synthetic_stream: {e}"))?;
        // The batcher's guarantee: with capacity unbounded (never
        // binds), service within the bound, and budget >= 2*bound, the
        // close rule leaves room for every admitted request.
        let bound_us = (deadline_ms * 1000.0 / 2.0).max(1.0) as u64;
        let service_us = 1 + rng.below(bound_us as usize) as u64;
        let rep = batcher::run(
            &reqs,
            &BatcherOpts { capacity: reqs.len(), service_bound_us: bound_us },
            |_batch| Ok(service_us),
        )
        .map_err(|e| format!("batcher: {e}"))?;
        if rep.misses != 0 {
            return Err(format!(
                "{} misses with service {service_us}us <= bound {bound_us}us and budget \
                 {deadline_ms}ms >= 2*bound",
                rep.misses
            ));
        }
        if rep.served != reqs.len() {
            return Err(format!("served {} of {} requests", rep.served, reqs.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_batches_respect_capacity_under_any_load() {
    proptest::run("serve_batch_capacity", |rng, _| {
        let g = generate(
            Preset::Mag,
            1e-4,
            &GenParams { seed: rng.next_u64(), ..Default::default() },
        );
        let reqs = synthetic_stream(
            &g,
            &StreamOpts {
                requests: 10 + rng.below(200),
                qps: 10.0 + rng.f64() * 50_000.0,
                deadline_ms: 0.5 + rng.f64() * 20.0,
                zipf_alpha: 1.1,
                seed: rng.next_u64(),
            },
        )
        .map_err(|e| format!("synthetic_stream: {e}"))?;
        let capacity = 1 + rng.below(16);
        // Service may breach the bound (overload): deadlines can miss,
        // but batch sizes and the served count must hold regardless.
        let service_us = 1 + rng.below(40_000) as u64;
        let mut seen = 0usize;
        let rep = batcher::run(
            &reqs,
            &BatcherOpts { capacity, service_bound_us: 500 },
            |batch| {
                if batch.is_empty() || batch.len() > capacity {
                    return Err(anyhow::anyhow!("batch of {} at capacity {capacity}", batch.len()));
                }
                seen += batch.len();
                Ok(service_us)
            },
        )
        .map_err(|e| format!("batcher: {e}"))?;
        if rep.max_batch > capacity {
            return Err(format!("max batch {} > capacity {capacity}", rep.max_batch));
        }
        if seen != reqs.len() || rep.served != reqs.len() {
            return Err(format!("served {}/{} ({} through exec)", rep.served, reqs.len(), seen));
        }
        Ok(())
    });
}

/// The tentpole invariant: every served embedding equals, byte for
/// byte, an independently-built fresh forward of its target alone
/// (slot 0 of a padded single-target batch through the same
/// forward-only plan) — whatever microbatch the batcher grouped it
/// into and whether it came from the cache or the compute path.
#[test]
fn served_embeddings_byte_identical_to_fresh_forward() {
    if !artifacts_ready("mag-tiny") {
        return;
    }
    let cfg = load_cfg();
    let opts = quick_opts();
    let rep = run_serve(&cfg, DIR, SystemKind::Heta, &opts, Backend::Channel)
        .expect("channel serve");
    assert_eq!(rep.served, opts.requests);
    assert_eq!(rep.embeds.len(), rep.served);

    // The reference path shares no state with the serving engine: a
    // fresh session, its own contexts, no frontier dedup, no cache.
    let mut sess = Session::new(&cfg, DIR).expect("reference session");
    let (mp, _) = meta_partition(&sess.g, cfg.train.num_partitions, cfg.model.layers, None);
    let plan = BatchPlan::forward_only(&sess.manifest, mp.num_parts).expect("forward-only plan");
    sess.params
        .ensure_artifacts(&sess.manifest, plan.workers.iter().map(|w| w.fwd_art.as_str()));
    let gpus = cfg.train.gpus_per_machine.max(1);
    let mut ctxs: Vec<ExecContext> = (0..mp.num_parts)
        .map(|p| {
            ExecContext::new(p, p % gpus, DIR, Arc::clone(&sess.manifest), None)
                .expect("reference context")
        })
        .collect();
    let mut arenas: Vec<BatchArena> = (0..mp.num_parts).map(|_| BatchArena::new()).collect();
    let world = EpochWorld {
        cfg: &cfg,
        g: &sess.g,
        tree: &sess.tree,
        store: &sess.store,
        gate: None,
        epoch_t0: Instant::now(),
    };
    let b = cfg.train.batch_size;
    let h = cfg.model.hidden;
    let seed = serve_seed(&cfg);
    let reqs = build_stream(&sess, &opts).expect("stream");
    assert_eq!(reqs.len(), rep.embeds.len());
    for (r, got) in reqs.iter().zip(&rep.embeds) {
        let mut chunk = vec![PAD; b];
        chunk[0] = r.target;
        let mut want = (vec![0f32; h], vec![0f32; h]);
        for p in 0..mp.num_parts {
            let filter = partition_edge_filter(&sess.tree, &mp, p);
            let sample =
                sample_tree(&sess.g, &sess.tree, &cfg.model.fanouts, &chunk, 0, seed, &filter);
            let fwd = plan.workers[p]
                .raf_forward(
                    &mut ctxs[p],
                    &world,
                    ParamsView::Owner(&sess.params),
                    &sample,
                    None,
                    &chunk,
                    0.0,
                    &mut arenas[p],
                )
                .expect("reference forward");
            for i in 0..h {
                want.0[i] += fwd.p1[i];
                want.1[i] += fwd.p2[i];
            }
        }
        assert_eq!(
            got, &want,
            "target {} must serve byte-identical to a fresh single-target forward",
            r.target
        );
    }
}

/// Engine label and transport must not change a single served byte:
/// Heta and the vanilla baseline share the forward-only decomposition,
/// and the loopback TCP star reproduces the channel run exactly.
#[test]
fn engines_and_transports_serve_identical_bytes() {
    if !artifacts_ready("mag-tiny") {
        return;
    }
    let cfg = load_cfg();
    let opts = quick_opts();
    let heta = run_serve(&cfg, DIR, SystemKind::Heta, &opts, Backend::Channel)
        .expect("heta channel serve");
    let vanilla = run_serve(&cfg, DIR, SystemKind::DglMetis, &opts, Backend::Channel)
        .expect("vanilla channel serve");
    assert_eq!(
        heta.embeds, vanilla.embeds,
        "Heta and the vanilla baseline must serve identical embeddings"
    );
    let tcp = run_loopback_tcp_serve(&cfg, DIR, SystemKind::Heta, &opts)
        .expect("loopback TCP serve");
    assert_eq!(tcp.served, opts.requests);
    assert_eq!(
        tcp.embeds, heta.embeds,
        "loopback TCP must serve the channel run's exact bytes"
    );
    assert!(tcp.wire.real_sent > 0, "TCP serving must move real bytes");
    assert!(tcp.wire.real_recv > 0);
}

/// Cache lifecycle: a repeat run serves entirely from cache; a
/// parameter-version bump and a store-generation bump each flush it;
/// and every recompute lands on the same bytes (the zero-grad Adam
/// step leaves weights bitwise unchanged, so the fixture has a real
/// invalidation with a known-good expected value).
#[test]
fn embed_cache_invalidates_on_param_and_store_updates() {
    if !artifacts_ready("mag-tiny") {
        return;
    }
    let cfg = load_cfg();
    let opts = quick_opts();
    let mut sess = Session::new(&cfg, DIR).expect("session");
    let mut eng = ServeEngine::new(&mut sess, SystemKind::Heta, &opts).expect("engine");
    let reqs = build_stream(&sess, &opts).expect("stream");

    let first = eng.run_channel(&sess, &reqs, &opts).expect("first run");
    assert!(first.ledger.computed_targets > 0);
    assert!(first.ledger.fetched_rows > 0, "a cold run must fetch features");

    // Same stamp: everything the first run computed is reusable.
    let warm = eng.run_channel(&sess, &reqs, &opts).expect("warm run");
    assert_eq!(warm.ledger.embed_misses, 0, "a warm repeat run must be all hits");
    assert_eq!(warm.ledger.computed_targets, 0);
    assert_eq!(warm.ledger.fetched_rows, 0, "all-hit batches must skip the forward entirely");
    assert_eq!(warm.embeds, first.embeds);

    // A parameter update lands: the stamp changes, the cache flushes,
    // and (zero gradient ⇒ bitwise-unchanged weights) the recompute
    // reproduces the original bytes.
    let weight = sess.manifest.artifacts["worker_fwd_p0"]
        .inputs
        .iter()
        .find(|i| i.kind == "weight")
        .expect("forward artifact declares a weight")
        .clone();
    let v0 = sess.params.version();
    sess.params
        .step(&weight.name, &vec![0.0; weight.shape.iter().product()])
        .expect("zero-grad step");
    assert!(sess.params.version() > v0);
    let after_param = eng.run_channel(&sess, &reqs, &opts).expect("post-update run");
    assert!(after_param.ledger.embed_invalidations >= 1, "param bump must invalidate");
    assert!(after_param.ledger.computed_targets > 0, "post-invalidation run must recompute");
    assert_eq!(after_param.embeds, first.embeds);

    // A learnable-feature store update: same flush through store_gen.
    eng.note_store_update();
    let after_store = eng.run_channel(&sess, &reqs, &opts).expect("post-store run");
    assert!(after_store.ledger.embed_invalidations >= 1, "store bump must invalidate");
    assert_eq!(after_store.embeds, first.embeds);

    // The A/B baseline arm: reuse off serves the same bytes with zero
    // hits and strictly more fetched rows per request.
    let no_reuse = ServeOpts { reuse: false, ..opts.clone() };
    let mut sess2 = Session::new(&cfg, DIR).expect("baseline session");
    let mut cold = ServeEngine::new(&mut sess2, SystemKind::Heta, &no_reuse).expect("baseline");
    let base = cold.run_channel(&sess2, &reqs, &no_reuse).expect("baseline run");
    assert_eq!(base.ledger.embed_hits, 0);
    assert_eq!(base.embeds, first.embeds);
    assert!(
        base.ledger.fetched_rows >= first.ledger.fetched_rows,
        "reuse must not fetch more rows than the no-reuse baseline"
    );
}
