//! Fault tolerance (the PR-7 tentpole).
//!
//! Artifact-free half: checkpoint codec round-trip property tests over
//! random parameter/learnable states (encode → decode must be
//! bit-identical and canonical), file-level corruption properties
//! (every truncation and every header flip is an `anyhow` error naming
//! the file — never a panic), and the `--fail rank:batch:kind[:epoch]`
//! spec grammar.
//!
//! Artifact-gated half (skipped until `make artifacts`): the recovery
//! determinism bar. A loopback-TCP cluster whose worker is killed by an
//! injected fault mid-epoch, then relaunched under the recovery
//! supervisor resuming from the epoch-boundary checkpoint, must produce
//! **byte-identical** per-batch losses to the fault-free run — for both
//! engines, at staleness 0 and k = 1, and for every fault kind (clean
//! exit, dropped sockets, a corrupted frame, a heartbeat-detected
//! stall). Checked through the shared `tests/common` matrix.

mod common;

use heta::ckpt::{self, Checkpoint};
use heta::config::{Config, FaultKind, FaultSpec};
use heta::coordinator::{run_loopback_tcp, run_loopback_tcp_ckpt, SystemKind};
use heta::kvstore::LearnableState;
use heta::net::codec::{decode_message, encode_message};
use heta::prop_assert;
use heta::runtime::{ParamEntry, ParamStoreState};
use heta::util::proptest;
use heta::util::rng::Rng;

use common::{variant_chaos, variant_tcp};

// ---- artifact-free: the fault-spec grammar ----

#[test]
fn fault_specs_parse_and_reject() {
    let f = FaultSpec::parse("1:2:exit").unwrap();
    assert_eq!((f.rank, f.batch, f.epoch, f.kind), (1, 2, 0, FaultKind::Exit));
    let f = FaultSpec::parse("2:0:drop-conn:1").unwrap();
    assert_eq!((f.rank, f.batch, f.epoch, f.kind), (2, 0, 1, FaultKind::DropConn));
    assert_eq!(FaultSpec::parse("1:3:stall").unwrap().kind, FaultKind::Stall);
    assert_eq!(
        FaultSpec::parse("1:3:corrupt-frame").unwrap().kind,
        FaultKind::CorruptFrame
    );
    for bad in ["", "1:2", "1:2:explode", "x:2:exit", "1:y:exit", "1:2:exit:z", "0:2:exit"] {
        assert!(FaultSpec::parse(bad).is_err(), "{bad:?} must be rejected");
    }
}

// ---- artifact-free: checkpoint codec properties ----

fn random_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f32() * 8.0 - 4.0).collect()
}

fn random_name(rng: &mut Rng) -> String {
    let n = 1 + rng.below(12);
    (0..n)
        .map(|_| char::from(b'a' + rng.below(26) as u8))
        .collect()
}

fn random_checkpoint(rng: &mut Rng) -> Checkpoint {
    let entries = (0..rng.below(4))
        .map(|_| {
            let n = rng.below(32);
            ParamEntry {
                name: random_name(rng),
                shape: vec![n],
                weight: random_f32s(rng, n),
                m: random_f32s(rng, n),
                v: random_f32s(rng, n),
                t: rng.below(1000) as i32,
            }
        })
        .collect();
    let learnable = (0..rng.below(3))
        .map(|_| {
            let n = rng.below(24);
            LearnableState {
                ty: rng.below(5),
                weight: random_f32s(rng, n),
                m: random_f32s(rng, n),
                v: random_f32s(rng, n),
            }
        })
        .collect();
    Checkpoint {
        epoch: rng.below(100),
        adam_t: rng.below(10_000) as i32,
        config_hash: rng.next_u64(),
        params: ParamStoreState {
            version: rng.next_u64(),
            entries,
        },
        learnable,
    }
}

#[test]
fn checkpoint_codec_round_trips_random_states() {
    proptest::run("checkpoint round-trip", |rng, _case| {
        let ck = random_checkpoint(rng);
        let bytes = encode_message(&ck);
        let back: Checkpoint = match decode_message(&bytes) {
            Ok(b) => b,
            Err(e) => return Err(format!("decode failed: {e:#}")),
        };
        prop_assert!(back == ck, "decoded checkpoint differs from the original");
        prop_assert!(
            encode_message(&back) == bytes,
            "re-encoding the decoded checkpoint is not canonical"
        );
        Ok(())
    });
}

#[test]
fn checkpoint_file_corruption_is_always_an_error_never_a_panic() {
    let dir = format!(
        "{}/heta-ft-corrupt-{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    let _ = std::fs::remove_dir_all(&dir);
    proptest::run("checkpoint corruption totality", |rng, _case| {
        let ck = random_checkpoint(rng);
        if let Err(e) = ckpt::save(&dir, &ck) {
            return Err(format!("save failed: {e:#}"));
        }
        let p = ckpt::path(&dir);
        let good = std::fs::read(&p).map_err(|e| format!("reading {p}: {e}"))?;

        // Any truncation is an error naming the file.
        let cut = rng.below(good.len());
        std::fs::write(&p, &good[..cut]).map_err(|e| e.to_string())?;
        match ckpt::load(&dir) {
            Ok(_) => return Err(format!("truncation at {cut}/{} was accepted", good.len())),
            Err(e) => prop_assert!(
                format!("{e:#}").contains(&p),
                "truncation error must name the file: {e:#}"
            ),
        }

        // Any header flip (magic or version) is an error.
        let mut bad = good.clone();
        let hi = rng.below(6);
        bad[hi] ^= 1 << rng.below(8);
        if bad != good {
            std::fs::write(&p, &bad).map_err(|e| e.to_string())?;
            prop_assert!(
                ckpt::load(&dir).is_err(),
                "header flip at byte {hi} was accepted"
            );
        }

        // A flip anywhere must never panic: either rejected, or decoded
        // into some (different) checkpoint when the flip landed inside
        // payload float data.
        let mut bad = good.clone();
        let bi = rng.below(bad.len());
        bad[bi] ^= 1 << rng.below(8);
        std::fs::write(&p, &bad).map_err(|e| e.to_string())?;
        let _ = ckpt::load(&dir);
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- artifact-gated: kill-and-recover byte-identity ----

const CFG: &str = "mag-tiny";
const EPOCHS: usize = 2;

/// The fault fires in epoch 1, so attempt one completes epoch 0 and
/// writes its boundary checkpoint; recovery must genuinely restore and
/// re-run epoch 1 rather than start over.
const KILL: &str = "1:2:exit:1";

#[test]
fn kill_and_recover_byte_identical_raf() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant_tcp("tcp/fault-free/k0", |_| {}),
            variant_chaos("tcp/kill-rank1/k0", |c| {
                c.train.fail = Some(FaultSpec::parse(KILL).unwrap());
            }),
        ],
    );
}

#[test]
fn kill_and_recover_byte_identical_raf_staleness_1() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant_tcp("tcp/fault-free/k1", |c| {
                c.train.staleness = 1;
            }),
            variant_chaos("tcp/kill-rank1/k1", |c| {
                c.train.staleness = 1;
                c.train.fail = Some(FaultSpec::parse(KILL).unwrap());
            }),
        ],
    );
}

#[test]
fn kill_and_recover_byte_identical_vanilla() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::DglMetis,
        EPOCHS,
        &[
            variant_tcp("tcp/fault-free/k0", |_| {}),
            variant_chaos("tcp/kill-rank1/k0", |c| {
                c.train.fail = Some(FaultSpec::parse(KILL).unwrap());
            }),
        ],
    );
}

#[test]
fn kill_and_recover_byte_identical_vanilla_staleness_1() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::DglMetis,
        EPOCHS,
        &[
            variant_tcp("tcp/fault-free/k1", |c| {
                c.train.staleness = 1;
            }),
            variant_chaos("tcp/kill-rank1/k1", |c| {
                c.train.staleness = 1;
                c.train.fail = Some(FaultSpec::parse(KILL).unwrap());
            }),
        ],
    );
}

/// Recovery through failure paths that are *not* a clean error return:
/// the worker hangs up every socket mid-epoch.
#[test]
fn drop_conn_recovers_byte_identical() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::DglMetis,
        EPOCHS,
        &[
            variant_tcp("tcp/fault-free/k0", |_| {}),
            variant_chaos("tcp/drop-conn-rank1/k0", |c| {
                c.train.fail = Some(FaultSpec::parse("1:1:drop-conn:1").unwrap());
            }),
        ],
    );
}

/// The worker's next outbound frame is bit-flipped: the leader's total
/// decode must reject it, fail the epoch, and recovery must replay it.
#[test]
fn corrupt_frame_recovers_byte_identical() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant_tcp("tcp/fault-free/k0", |_| {}),
            variant_chaos("tcp/corrupt-frame-rank1/k0", |c| {
                c.train.fail = Some(FaultSpec::parse("1:1:corrupt-frame:1").unwrap());
            }),
        ],
    );
}

/// A wedged-but-alive worker: it pauses heartbeats and sleeps past the
/// leader's deadline, so the epoch ends because the *leader* declared
/// the rank dead — recovery goes through failure detection.
#[test]
fn heartbeat_detected_stall_recovers_byte_identical() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant_tcp("tcp/fault-free/k0", |_| {}),
            variant_chaos("tcp/stall-rank1/k0", |c| {
                c.train.fail = Some(FaultSpec::parse("1:1:stall:1").unwrap());
                // Tight heartbeat timing keeps the detect-and-recover
                // cycle fast; heartbeat knobs never affect the losses.
                c.train.hb_interval_ms = 100;
                c.train.hb_timeout_ms = 400;
            }),
        ],
    );
}

/// The recovery *shape*, pinned directly against the one-attempt API:
/// attempt one completes exactly epoch 0 and dies; attempt two (fault
/// cleared, resuming from the checkpoint) runs exactly epoch 1; the
/// concatenation is byte-identical to the fault-free trajectory.
#[test]
fn recovery_restores_the_killed_epoch_not_the_whole_run() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    let cfg = Config::load(&format!("configs/{CFG}.json")).unwrap();
    let dir = format!("artifacts/{CFG}");
    let ckpt_dir = format!(
        "{}/heta-ft-shape-{}",
        std::env::temp_dir().display(),
        std::process::id()
    );
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    let reference = run_loopback_tcp(&cfg, &dir, SystemKind::Heta, EPOCHS).unwrap();

    let mut faulty = cfg.clone();
    faulty.train.fail = Some(FaultSpec::parse(KILL).unwrap());
    let (first, err) = run_loopback_tcp_ckpt(&faulty, &dir, SystemKind::Heta, EPOCHS, &ckpt_dir);
    assert!(err.is_some(), "the injected exit must fail the first attempt");
    assert_eq!(first.len(), 1, "attempt one must complete exactly epoch 0");

    faulty.train.fail = None;
    let (second, err) =
        run_loopback_tcp_ckpt(&faulty, &dir, SystemKind::Heta, EPOCHS, &ckpt_dir);
    assert!(err.is_none(), "the clean relaunch must finish: {err:?}");
    assert_eq!(second.len(), 1, "attempt two must resume at epoch 1, not epoch 0");

    let recovered: Vec<_> = first.iter().chain(second.iter()).collect();
    for (ep, (r, c)) in reference.iter().zip(recovered).enumerate() {
        assert_eq!(r.batch_losses.len(), c.batch_losses.len(), "epoch {ep} batch count");
        for (bi, (a, b)) in r.batch_losses.iter().zip(&c.batch_losses).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "epoch {ep} batch {bi}: recovered loss {b} != fault-free {a}"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&ckpt_dir);
}
