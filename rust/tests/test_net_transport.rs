//! The wire transport (the PR-5 tentpole).
//!
//! Artifact-free half: codec round-trip property tests over random
//! payloads (every shared cluster-message component, plus truncated
//! and bit-flipped frame rejection — decode must be total), and the
//! generic collectives running over **real loopback sockets**: a
//! worker-id-ordered gather, a barrier, modeled-vs-real byte
//! accounting, and hangups surfacing as errors naming the peer.
//!
//! Artifact-gated half (skipped until `make artifacts`): the
//! equivalence bar of every prior PR, now across transports —
//! `transport = channel | tcp` must produce **byte-identical**
//! per-batch losses for both engines at staleness 0 and at a fixed
//! staleness window `k = 1`, checked through the shared `tests/common`
//! matrix (the tcp variants run one Session per rank over loopback
//! sockets — separate feature/parameter stores, learnable updates
//! replicated by store deltas). Plus the wire-accounting satellite:
//! real frame bytes move, and modeled bytes never exceed them.

mod common;

use heta::cluster::collective::{Hub, Port};
use heta::cluster::mailbox::{Transport, Wire};
use heta::config::RuntimeKind;
use heta::coordinator::SystemKind;
use heta::exec::WorkerGrads;
use heta::kvstore::StoreDelta;
use heta::net::codec::{decode_message, encode_message, ByteReader, ByteWriter, WireCodec};
use heta::net::tcp;
use heta::runtime::ParamSnapshot;
use heta::util::proptest;
use heta::util::rng::Rng;

use common::{variant, variant_tcp};

// ---- artifact-free: codec properties ----

fn random_f32s(rng: &mut Rng, max: usize) -> Vec<f32> {
    (0..rng.below(max)).map(|_| rng.f32() * 8.0 - 4.0).collect()
}

fn random_name(rng: &mut Rng) -> String {
    let n = 1 + rng.below(12);
    (0..n)
        .map(|_| char::from(b'a' + rng.below(26) as u8))
        .collect()
}

fn random_grads(rng: &mut Rng) -> WorkerGrads {
    WorkerGrads {
        wgrads: (0..rng.below(4))
            .map(|_| (random_name(rng), random_f32s(rng, 32)))
            .collect(),
        row_grads: (0..rng.below(3))
            .map(|_| {
                let ids: Vec<u32> = (0..rng.below(16)).map(|_| rng.below(1000) as u32).collect();
                let g = random_f32s(rng, 64);
                (rng.below(5), ids, g)
            })
            .collect(),
        gx: (0..rng.below(3)).map(|_| random_f32s(rng, 16)).collect(),
        learnable_rows: (0..rng.below(3))
            .map(|_| (rng.below(5), rng.below(100) as u64, rng.below(100) as u64))
            .collect(),
        param_version: rng.next_u64(),
    }
}

#[test]
fn prop_worker_grads_round_trip_bitwise() {
    proptest::run("codec_worker_grads", |rng, _| {
        let wg = random_grads(rng);
        let bytes = encode_message(&wg);
        let back: WorkerGrads =
            decode_message(&bytes).map_err(|e| format!("decode failed: {e:#}"))?;
        heta::prop_assert!(back == wg, "round trip changed the payload: {wg:?} -> {back:?}");
        Ok(())
    });
}

#[test]
fn prop_param_snapshots_round_trip_bitwise() {
    proptest::run("codec_param_snapshot", |rng, _| {
        let tensors: Vec<(String, Vec<f32>)> = (0..rng.below(5))
            .map(|_| (random_name(rng), random_f32s(rng, 64)))
            .collect();
        let snap = ParamSnapshot::from_tensors(rng.next_u64(), tensors);
        let bytes = encode_message(&snap);
        let back: ParamSnapshot =
            decode_message(&bytes).map_err(|e| format!("decode failed: {e:#}"))?;
        heta::prop_assert!(back == snap, "snapshot changed in flight");
        heta::prop_assert!(
            back.version == snap.version,
            "version must survive: {} != {}",
            back.version,
            snap.version
        );
        Ok(())
    });
}

#[test]
fn prop_store_deltas_round_trip_bitwise() {
    proptest::run("codec_store_delta", |rng, _| {
        let rows = (0..rng.below(4))
            .map(|_| {
                let n = rng.below(8);
                let dim = 1 + rng.below(6);
                let ids: Vec<u32> = (0..n).map(|i| (i * 3) as u32).collect();
                let vals = (0..n * dim).map(|_| rng.f32()).collect();
                (rng.below(4), ids, vals)
            })
            .collect();
        let delta = StoreDelta { rows };
        let back: StoreDelta = decode_message(&encode_message(&delta))
            .map_err(|e| format!("decode failed: {e:#}"))?;
        heta::prop_assert!(back == delta, "delta changed in flight");
        Ok(())
    });
}

#[test]
fn prop_truncated_and_corrupt_frames_never_panic() {
    proptest::run("codec_corruption", |rng, _| {
        let wg = random_grads(rng);
        let bytes = encode_message(&wg);
        // Any truncation is an error (and must not panic or allocate
        // absurdly — the reader validates lengths against remainders).
        let cut = rng.below(bytes.len().max(1));
        heta::prop_assert!(
            decode_message::<WorkerGrads>(&bytes[..cut]).is_err(),
            "truncation at {cut}/{} must be rejected",
            bytes.len()
        );
        // A random bit flip either still decodes (flipped a float bit)
        // or errors — both fine; a panic or wrong-success is not. The
        // call itself is the assertion (panics fail the property).
        if !bytes.is_empty() {
            let mut corrupt = bytes.clone();
            let at = rng.below(corrupt.len());
            corrupt[at] ^= 1 << rng.below(8);
            let _ = decode_message::<WorkerGrads>(&corrupt);
        }
        Ok(())
    });
}

// ---- artifact-free: the generic collectives over real sockets ----

/// A tiny gather payload: one f32 vector per worker.
#[derive(Debug, Clone, PartialEq)]
struct Contribution {
    round: u64,
    data: Vec<f32>,
}

impl Wire for Contribution {
    fn wire_bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

impl WireCodec for Contribution {
    fn encode(&self, w: &mut ByteWriter) {
        w.u64(self.round);
        w.f32s(&self.data);
    }
    fn decode(r: &mut ByteReader<'_>) -> anyhow::Result<Self> {
        Ok(Contribution {
            round: r.u64()?,
            data: r.f32s()?,
        })
    }
}

/// Build a loopback star of `workers` TCP nodes plus the leader.
fn loopback_nodes(workers: usize) -> (tcp::TcpNode, Vec<tcp::TcpNode>) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let dialers: Vec<_> = (0..workers)
        .map(|w| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                tcp::dial(&addr, w, workers, tcp::DIAL_TIMEOUT).expect("dial")
            })
        })
        .collect();
    let leader = tcp::accept_workers(listener, workers).expect("accept");
    (leader, dialers.into_iter().map(|h| h.join().expect("join")).collect())
}

#[test]
fn collectives_over_sockets_gather_in_worker_order() {
    let workers = 3;
    let (leader, nodes) = loopback_nodes(workers);
    let handles: Vec<_> = nodes
        .into_iter()
        .map(|node| {
            std::thread::spawn(move || {
                let up = node
                    .open_lane::<Contribution>(tcp::LANE_DATA_UP)
                    .expect("worker up lane");
                let down = node.open_lane::<()>(tcp::LANE_DATA_DOWN).expect("worker down lane");
                let port = Port::<Contribution, (), _, _>::from_endpoints(&up, &down, workers);
                // Stagger sends so arrival order != worker order.
                std::thread::sleep(std::time::Duration::from_millis(
                    (10 * (workers - node.rank())) as u64,
                ));
                port.send(Contribution {
                    round: 0,
                    data: vec![node.rank() as f32; 2],
                })
                .expect("send contribution");
                port.recv().expect("barrier release");
            })
        })
        .collect();
    let up = leader
        .open_lane::<Contribution>(tcp::LANE_DATA_UP)
        .expect("leader up lane");
    let down = leader.open_lane::<()>(tcp::LANE_DATA_DOWN).expect("leader down lane");
    let hub = Hub::<Contribution, (), _, _>::from_endpoints(&up, &down, workers);
    let got = hub.gather().expect("gather");
    let ranks: Vec<f32> = got.iter().map(|c| c.data[0]).collect();
    assert_eq!(ranks, vec![0.0, 1.0, 2.0], "worker-id order, not arrival order");
    hub.broadcast(()).expect("release");
    for h in handles {
        h.join().expect("worker thread");
    }
    // The wire-accounting satellite, at transport level: bytes really
    // moved, and the modeled tensor bytes never exceed the real frame
    // bytes that carried them.
    let t = leader.traffic();
    assert!(t.real_recv > 0 && t.frames_recv == 3, "real frames must be counted: {t:?}");
    assert_eq!(t.modeled_recv, 3 * 8, "two f32 per worker are the modeled payload");
    assert!(t.modeled_recv <= t.real_recv, "modeled must never exceed real: {t:?}");
    assert!(t.modeled_sent <= t.real_sent, "{t:?}");
}

#[test]
fn socket_hangup_surfaces_as_an_error_naming_the_peer() {
    let (leader, mut nodes) = loopback_nodes(1);
    let up = leader
        .open_lane::<Contribution>(tcp::LANE_DATA_UP)
        .expect("leader up lane");
    drop(nodes.pop()); // the worker process "dies" before contributing
    let err = up.recv().expect_err("a dead peer must not hang the gather");
    let text = format!("{err:#}");
    assert!(
        text.contains("rank 0"),
        "the error must name the dead peer: {text}"
    );
}

// ---- artifact-gated: cross-transport byte-identity ----

const CFG: &str = "mag-tiny";
const EPOCHS: usize = 2;

#[test]
fn losses_byte_identical_channel_vs_tcp_raf() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    let reports = common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant("cluster/channel/k0", |c| {
                c.train.runtime = RuntimeKind::Cluster;
            }),
            variant_tcp("cluster/tcp-loopback/k0", |_| {}),
        ],
    );
    // The satellite's accounting bar: the tcp run moved real bytes and
    // its modeled bytes (tensor payloads only) never exceed them.
    for rep in &reports[1] {
        assert!(rep.wire.frames() > 0, "the tcp leader must have counted frames");
        assert!(rep.wire.real_total() > 0);
        assert!(
            rep.wire.modeled_total() <= rep.wire.real_total(),
            "modeled {} > real {} — the cost model claims more than the wire carried",
            rep.wire.modeled_total(),
            rep.wire.real_total()
        );
    }
    // And the channel run moved none (it has no wire).
    for rep in &reports[0] {
        assert_eq!(rep.wire.frames(), 0, "in-process transport moves no frames");
    }
}

#[test]
fn losses_byte_identical_channel_vs_tcp_raf_staleness_1() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant("cluster/channel/k1", |c| {
                c.train.runtime = RuntimeKind::Cluster;
                c.train.staleness = 1;
            }),
            variant_tcp("cluster/tcp-loopback/k1", |c| {
                c.train.staleness = 1;
            }),
        ],
    );
}

#[test]
fn losses_byte_identical_channel_vs_tcp_vanilla() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::DglMetis,
        EPOCHS,
        &[
            variant("cluster/channel/k0", |c| {
                c.train.runtime = RuntimeKind::Cluster;
            }),
            variant_tcp("cluster/tcp-loopback/k0", |_| {}),
        ],
    );
}

#[test]
fn losses_byte_identical_channel_vs_tcp_vanilla_staleness_1() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::DglMetis,
        EPOCHS,
        &[
            variant("cluster/channel/k1", |c| {
                c.train.runtime = RuntimeKind::Cluster;
                c.train.staleness = 1;
            }),
            variant_tcp("cluster/tcp-loopback/k1", |c| {
                c.train.staleness = 1;
            }),
        ],
    );
}

/// GraphLearn caches + learnable tables exercise the store-delta
/// replication hardest (per-type partitioning keeps learnable rows on
/// every worker's fetch path).
#[test]
fn losses_byte_identical_channel_vs_tcp_graphlearn() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::GraphLearn,
        EPOCHS,
        &[
            variant("cluster/channel/k0", |c| {
                c.train.runtime = RuntimeKind::Cluster;
            }),
            variant_tcp("cluster/tcp-loopback/k0", |_| {}),
        ],
    );
}

/// The windowed schedule under replication: a Ready-before-Store
/// ordering bug would surface exactly here, where releases run ahead
/// of the updates whose deltas the marshals must (not yet) see.
#[test]
fn losses_byte_identical_channel_vs_tcp_graphlearn_staleness_1() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    common::assert_losses_identical(
        CFG,
        SystemKind::GraphLearn,
        EPOCHS,
        &[
            variant("cluster/channel/k1", |c| {
                c.train.runtime = RuntimeKind::Cluster;
                c.train.staleness = 1;
            }),
            variant_tcp("cluster/tcp-loopback/k1", |c| {
                c.train.staleness = 1;
            }),
        ],
    );
}
