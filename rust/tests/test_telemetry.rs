//! The live telemetry plane (the PR-10 tentpole).
//!
//! One sequential test, deliberately: arming the plane
//! (`obs::http::start`) is process-global and sticky, so the unarmed
//! reference trajectories must be captured *before* the process ever
//! arms — multiple `#[test]` functions run on parallel threads and
//! could not guarantee that order.
//!
//! Phases:
//!
//! 1. (artifact-gated) Unarmed reference: both engines × both
//!    transports through the shared `tests/common` harness.
//! 2. Arm the plane on an ephemeral loopback port and start a scraper
//!    thread that hammers `/metrics` + `/healthz` continuously.
//! 3. (artifact-free) Exposition semantics over real HTTP: scrapes are
//!    cumulative and non-draining, histograms expose the bucket
//!    ladder, `/healthz` and `/buildinfo` parse.
//! 4. (artifact-gated) Re-run the phase-1 matrix armed and under
//!    continuous scraping; losses must be **byte-identical** to the
//!    unarmed reference, and the post-run scrape must carry the
//!    `wire.lane*` and `cache.*` families.

mod common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use heta::config::RuntimeKind;
use heta::coordinator::SystemKind;
use heta::metrics::EpochReport;

use common::{run_reports_on, Runner};

const CFG: &str = "mag-tiny";
const EPOCHS: usize = 2;

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect to the telemetry listener");
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n")
        .expect("write request");
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read response");
    buf
}

/// Split an HTTP/1.1 response into (status line, body).
fn split_response(raw: &str) -> (&str, &str) {
    let status = raw.lines().next().unwrap_or("");
    let body = raw.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    (status, body)
}

/// Run the PR-9 equivalence surface: both engines over the in-process
/// cluster transport and the loopback-TCP star.
fn run_matrix(phase: &str) -> Vec<(String, Vec<EpochReport>)> {
    let mut out = Vec::new();
    for system in [SystemKind::Heta, SystemKind::DglMetis] {
        let label = format!("{phase}/{system:?}/cluster");
        let reps = run_reports_on(
            CFG,
            system,
            EPOCHS,
            &label,
            |c| c.train.runtime = RuntimeKind::Cluster,
            Runner::InProcess,
        );
        out.push((label, reps));
        let label = format!("{phase}/{system:?}/tcp");
        let reps = run_reports_on(CFG, system, EPOCHS, &label, |_| {}, Runner::LoopbackTcp);
        out.push((label, reps));
    }
    out
}

/// Bitwise trajectory equality, batch by batch, with the first
/// diverging index in the failure message.
fn assert_identical(reference: &[(String, Vec<EpochReport>)], armed: &[(String, Vec<EpochReport>)]) {
    assert_eq!(reference.len(), armed.len());
    for ((ref_label, r_reps), (armed_label, a_reps)) in reference.iter().zip(armed) {
        assert_eq!(r_reps.len(), a_reps.len(), "[{armed_label}] epoch count");
        for (ep, (r, a)) in r_reps.iter().zip(a_reps).enumerate() {
            assert_eq!(
                r.batch_losses.len(),
                a.batch_losses.len(),
                "[{armed_label}] epoch {ep}: batch count diverged from [{ref_label}]",
            );
            for (bi, (x, y)) in r.batch_losses.iter().zip(&a.batch_losses).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "[{armed_label}] diverged from [{ref_label}] at epoch {ep} batch {bi}: \
                     {y} != {x} — arming the telemetry plane (and scraping it mid-run) \
                     must not perturb training",
                );
            }
            assert_eq!(r.loss_mean, a.loss_mean, "[{armed_label}] epoch {ep}: loss mean");
            assert_eq!(r.accuracy, a.accuracy, "[{armed_label}] epoch {ep}: accuracy");
        }
    }
}

#[test]
fn telemetry_plane_is_observationally_free_and_scrapable() {
    // Nothing in this binary may have armed the plane yet — that is
    // exactly why this file holds a single test function.
    assert!(
        !heta::obs::enabled(),
        "the recorder is already on: the unarmed reference would be meaningless"
    );

    // -- phase 1: unarmed reference trajectories (artifact-gated) --
    let gated = heta::util::artifacts_ready(CFG);
    let reference = gated.then(|| run_matrix("unarmed"));

    // -- phase 2: arm + hammer --
    let srv = heta::obs::http::start("127.0.0.1:0", 0, "leader").expect("bind telemetry");
    let addr = srv.addr;
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut scrapes = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let (status, _) = split_response(&http_get(addr, "/metrics"));
                assert!(status.contains("200"), "mid-run /metrics scrape failed: {status}");
                // /healthz may be 200 or 503; it must always answer.
                let raw = http_get(addr, "/healthz");
                assert!(!raw.is_empty(), "mid-run /healthz scrape got an empty response");
                scrapes += 1;
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            scrapes
        })
    };

    // -- phase 3: exposition semantics over real HTTP --
    heta::obs::counter_add("telemetry.e2e.ticks", 3);
    heta::obs::gauge_set("telemetry.e2e.level", 1.5);
    heta::obs::hist_observe("telemetry.e2e.lat_ms", 2.0);
    let (_, first) = {
        let raw = http_get(addr, "/metrics");
        let (s, b) = split_response(&raw);
        (s.to_string(), b.to_string())
    };
    let raw = http_get(addr, "/metrics");
    let (_, second) = split_response(&raw);
    for (i, page) in [first.as_str(), second].into_iter().enumerate() {
        // Identical on both scrapes: /metrics reads the cumulative
        // view and never drains the epoch deltas.
        assert!(
            page.contains("telemetry_e2e_ticks{rank=\"0\"} 3"),
            "scrape {i} lost the counter:\n{page}"
        );
        assert!(page.contains("telemetry_e2e_level{rank=\"0\"} 1.5"), "scrape {i}: gauge");
        // The 2.0 ms sample lands in the 2.5 ms bucket and the +Inf
        // bucket equals the count.
        assert!(
            page.contains("telemetry_e2e_lat_ms_bucket{rank=\"0\",le=\"2.5\"} 1"),
            "scrape {i}: bucket ladder"
        );
        assert!(
            page.contains("telemetry_e2e_lat_ms_bucket{rank=\"0\",le=\"+Inf\"} 1"),
            "scrape {i}: +Inf bucket"
        );
        assert!(page.contains("telemetry_e2e_lat_ms_count{rank=\"0\"} 1"), "scrape {i}: count");
    }
    let raw = http_get(addr, "/healthz");
    let (_, body) = split_response(&raw);
    let health = heta::util::json::parse(body).expect("/healthz body must be JSON");
    assert_eq!(health.get("role").as_str(), Some("leader"));
    assert!(health.get("status").as_str().is_some());
    let raw = http_get(addr, "/buildinfo");
    let (status, body) = split_response(&raw);
    assert!(status.contains("200"), "/buildinfo: {status}");
    let info = heta::util::json::parse(body).expect("/buildinfo body must be JSON");
    assert_eq!(info.get("name").as_str(), Some("heta"));

    // -- phase 4: armed + scraped runs match the reference bitwise --
    if let Some(reference) = reference {
        let armed = run_matrix("armed");
        assert_identical(&reference, &armed);
        // The acceptance families are live after a TCP training run:
        // lane traffic and per-node-type cache counters ticked with no
        // --trace flag, purely from arming.
        let raw = http_get(addr, "/metrics");
        let (_, page) = split_response(&raw);
        assert!(
            page.contains("wire_lane"),
            "armed TCP run exposed no wire.lane* family:\n{page}"
        );
        assert!(
            page.contains("cache_"),
            "armed run exposed no cache.* family:\n{page}"
        );
        // Training progress reached /healthz via the recorder's batch
        // tag (no clock reads, no extra instrumentation in the loop).
        let raw = http_get(addr, "/healthz");
        let (_, body) = split_response(&raw);
        let health = heta::util::json::parse(body).expect("/healthz body must be JSON");
        assert!(
            health.get("batch").as_f64().is_some(),
            "armed run left /healthz batch progress null: {body}"
        );
    }

    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().expect("scraper thread");
    assert!(scrapes > 0, "the scraper thread never completed a scrape");
}
