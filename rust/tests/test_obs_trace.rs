//! The flight recorder (the PR-6 tentpole).
//!
//! Artifact-free half: codec round-trip property tests over random
//! trace payloads — `TraceTrack`, `MetricsSnapshot` and the `TraceBlob`
//! the workers ship to the leader at epoch end — plus truncated and
//! bit-flipped frame rejection (decode must be total), and a check
//! that the Chrome-trace exporter emits JSON our own parser round-trips
//! with one process (`pid`) per recorded rank.
//!
//! Artifact-gated half (skipped until `make artifacts`): the PR's hard
//! invariant. Tracing must be **observationally free**: per-batch
//! losses byte-identical with `--trace` on vs off, for both engines,
//! across the in-process and loopback-TCP transports, at staleness 0
//! and at a fixed window k = 1 — through the shared `tests/common`
//! matrix. And it must actually observe: every trace-on run's report
//! carries non-empty tracks.
//!
//! (Only *track* content is asserted, never registry metrics: tracks
//! travel thread-locally into each rank's blob, while the process-wide
//! metrics registry and reader-thread sink are shared across the
//! concurrently running tests of this binary.)

mod common;

use heta::config::RuntimeKind;
use heta::coordinator::SystemKind;
use heta::metrics::EpochReport;
use heta::net::codec::{decode_message, encode_message};
use heta::obs::{
    chrome_trace_json, HistSummary, MetricsSnapshot, ObsEvent, ObsReport, TraceBlob, TraceTrack,
    KIND_BARRIER_WAIT, KIND_COMPUTE, KIND_MARSHAL, KIND_WIRE_WAIT, LANE_NONE, NO_BATCH_U64,
};
use heta::util::proptest;
use heta::util::rng::Rng;

use common::{variant, variant_tcp};

// ---- artifact-free: codec properties ----

fn random_name(rng: &mut Rng) -> String {
    let n = 1 + rng.below(12);
    (0..n)
        .map(|_| char::from(b'a' + rng.below(26) as u8))
        .collect()
}

fn random_event(rng: &mut Rng, num_names: usize) -> ObsEvent {
    let kinds = [KIND_COMPUTE, KIND_MARSHAL, KIND_WIRE_WAIT, KIND_BARRIER_WAIT];
    let t0 = rng.next_u64() % 1_000_000_000;
    ObsEvent {
        batch: if rng.below(4) == 0 { NO_BATCH_U64 } else { rng.below(64) as u64 },
        kind: kinds[rng.below(4)],
        lane: if rng.below(3) == 0 { LANE_NONE } else { rng.below(4) as u8 },
        name_idx: rng.below(num_names.max(1)) as u16,
        t0_us: t0,
        t1_us: t0 + rng.below(50_000) as u64,
    }
}

fn random_track(rng: &mut Rng) -> TraceTrack {
    let names: Vec<String> = (0..1 + rng.below(6)).map(|_| random_name(rng)).collect();
    TraceTrack {
        rank: rng.below(6) as u32,
        thread: random_name(rng),
        dropped: rng.below(3) as u64,
        events: (0..rng.below(24)).map(|_| random_event(rng, names.len())).collect(),
        names,
    }
}

fn random_metrics(rng: &mut Rng) -> MetricsSnapshot {
    let mut m = MetricsSnapshot::default();
    for _ in 0..rng.below(5) {
        m.counters.push((random_name(rng), rng.next_u64()));
    }
    for _ in 0..rng.below(4) {
        m.gauges.push((random_name(rng), rng.f32() as f64 * 16.0 - 8.0));
    }
    for _ in 0..rng.below(3) {
        let mut h = HistSummary::default();
        for _ in 0..1 + rng.below(8) {
            h.observe(rng.f32() as f64 * 10.0);
        }
        m.hists.push((random_name(rng), h));
    }
    m
}

fn random_blob(rng: &mut Rng) -> TraceBlob {
    TraceBlob {
        rank: rng.below(6) as u32,
        tracks: (0..rng.below(4)).map(|_| random_track(rng)).collect(),
        metrics: random_metrics(rng),
    }
}

#[test]
fn prop_trace_track_round_trip_bitwise() {
    proptest::run("codec_trace_track", |rng, _| {
        let track = random_track(rng);
        let back: TraceTrack = decode_message(&encode_message(&track))
            .map_err(|e| format!("decode failed: {e:#}"))?;
        heta::prop_assert!(back == track, "track changed in flight: {track:?} -> {back:?}");
        Ok(())
    });
}

#[test]
fn prop_metrics_snapshot_round_trip_bitwise() {
    proptest::run("codec_metrics_snapshot", |rng, _| {
        let m = random_metrics(rng);
        let back: MetricsSnapshot = decode_message(&encode_message(&m))
            .map_err(|e| format!("decode failed: {e:#}"))?;
        heta::prop_assert!(back == m, "snapshot changed in flight: {m:?} -> {back:?}");
        Ok(())
    });
}

#[test]
fn prop_trace_blob_round_trip_bitwise() {
    proptest::run("codec_trace_blob", |rng, _| {
        let blob = random_blob(rng);
        let back: TraceBlob = decode_message(&encode_message(&blob))
            .map_err(|e| format!("decode failed: {e:#}"))?;
        heta::prop_assert!(back == blob, "blob changed in flight");
        Ok(())
    });
}

#[test]
fn prop_truncated_and_corrupt_trace_frames_never_panic() {
    proptest::run("codec_trace_corruption", |rng, _| {
        let blob = random_blob(rng);
        let bytes = encode_message(&blob);
        let cut = rng.below(bytes.len().max(1));
        heta::prop_assert!(
            decode_message::<TraceBlob>(&bytes[..cut]).is_err(),
            "truncation at {cut}/{} must be rejected",
            bytes.len()
        );
        // A random bit flip either still decodes or errors — both fine;
        // a panic or absurd allocation is not.
        if !bytes.is_empty() {
            let mut corrupt = bytes.clone();
            let at = rng.below(corrupt.len());
            corrupt[at] ^= 1 << rng.below(8);
            let _ = decode_message::<TraceBlob>(&corrupt);
        }
        Ok(())
    });
}

// ---- artifact-free: the exporter against our own JSON parser ----

#[test]
fn prop_chrome_export_parses_with_one_pid_per_rank() {
    proptest::run("chrome_export", |rng, _| {
        let report = ObsReport {
            tracks: (0..1 + rng.below(4)).map(|_| random_track(rng)).collect(),
            metrics: random_metrics(rng),
        };
        let text = chrome_trace_json(&report).to_string();
        let json = heta::util::json::parse(&text).map_err(|e| format!("exported trace must parse: {e:#}"))?;
        let events = json.get("traceEvents").as_arr().ok_or("traceEvents must be an array")?;
        let spans = events.iter().filter(|e| e.get("ph").as_str() == Some("X")).count();
        let total: usize = report.tracks.iter().map(|t| t.events.len()).sum();
        heta::prop_assert!(spans == total, "{spans} X events for {total} recorded spans");
        let pids: std::collections::BTreeSet<u64> =
            events.iter().filter_map(|e| e.get("pid").as_u64()).collect();
        let ranks: std::collections::BTreeSet<u64> =
            report.tracks.iter().map(|t| t.rank as u64).collect();
        heta::prop_assert!(pids == ranks, "pids {pids:?} must cover exactly the ranks {ranks:?}");
        Ok(())
    });
}

// ---- artifact-gated: tracing must be observationally free ----

const CFG: &str = "mag-tiny";
const EPOCHS: usize = 2;

/// Every trace-on report must carry at least one non-empty track —
/// otherwise the "identical losses" half of the invariant is vacuous.
fn assert_traced(label: &str, reports: &[EpochReport]) {
    for (ep, rep) in reports.iter().enumerate() {
        let events: usize = rep.obs.tracks.iter().map(|t| t.events.len()).sum();
        assert!(
            !rep.obs.tracks.is_empty() && events > 0,
            "[{label}] epoch {ep}: tracing was on but the report has \
             {} tracks / {events} events",
            rep.obs.tracks.len(),
        );
    }
}

#[test]
fn losses_byte_identical_tracing_on_vs_off_raf() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    let reports = common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant("seq/trace-off", |_| {}),
            variant("seq/trace-on", |c| c.train.trace = true),
            variant("cluster/trace-on", |c| {
                c.train.runtime = RuntimeKind::Cluster;
                c.train.trace = true;
            }),
            variant_tcp("tcp/trace-on", |c| c.train.trace = true),
        ],
    );
    assert_traced("seq/trace-on", &reports[1]);
    assert_traced("cluster/trace-on", &reports[2]);
    assert_traced("tcp/trace-on", &reports[3]);
}

#[test]
fn losses_byte_identical_tracing_on_vs_off_raf_staleness_1() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    let k1 = |c: &mut heta::config::Config| {
        c.train.runtime = RuntimeKind::Cluster;
        c.train.staleness = 1;
    };
    let reports = common::assert_losses_identical(
        CFG,
        SystemKind::Heta,
        EPOCHS,
        &[
            variant("cluster/k1/trace-off", k1),
            variant("cluster/k1/trace-on", move |c| {
                k1(c);
                c.train.trace = true;
            }),
            variant_tcp("tcp/k1/trace-on", |c| {
                c.train.staleness = 1;
                c.train.trace = true;
            }),
        ],
    );
    assert_traced("cluster/k1/trace-on", &reports[1]);
    assert_traced("tcp/k1/trace-on", &reports[2]);
}

#[test]
fn losses_byte_identical_tracing_on_vs_off_vanilla() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    let reports = common::assert_losses_identical(
        CFG,
        SystemKind::DglMetis,
        EPOCHS,
        &[
            variant("seq/trace-off", |_| {}),
            variant("seq/trace-on", |c| c.train.trace = true),
            variant("cluster/trace-on", |c| {
                c.train.runtime = RuntimeKind::Cluster;
                c.train.trace = true;
            }),
            variant_tcp("tcp/trace-on", |c| c.train.trace = true),
        ],
    );
    assert_traced("seq/trace-on", &reports[1]);
    assert_traced("cluster/trace-on", &reports[2]);
    assert_traced("tcp/trace-on", &reports[3]);
}

#[test]
fn losses_byte_identical_tracing_on_vs_off_vanilla_staleness_1() {
    if !heta::util::artifacts_ready(CFG) {
        return;
    }
    let k1 = |c: &mut heta::config::Config| {
        c.train.runtime = RuntimeKind::Cluster;
        c.train.staleness = 1;
    };
    let reports = common::assert_losses_identical(
        CFG,
        SystemKind::DglMetis,
        EPOCHS,
        &[
            variant("cluster/k1/trace-off", k1),
            variant("cluster/k1/trace-on", move |c| {
                k1(c);
                c.train.trace = true;
            }),
            variant_tcp("tcp/k1/trace-on", |c| {
                c.train.staleness = 1;
                c.train.trace = true;
            }),
        ],
    );
    assert_traced("cluster/k1/trace-on", &reports[1]);
    assert_traced("tcp/k1/trace-on", &reports[2]);
}
