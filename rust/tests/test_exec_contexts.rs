//! Per-worker execution contexts (the PR-3 tentpole).
//!
//! Artifact-free half: a compile-level `Send` proof that moving an
//! [`ExecContext`] to a worker thread needs no lock, a source scan
//! pinning the cluster runtime lock-free (no `Mutex` anywhere under
//! `src/cluster/` — the shared-session mutex of PR 1 is gone, and the
//! poison-handling `lock()` helper with it), and unit-level checks of
//! the copy-on-write parameter snapshots the leader broadcasts.
//!
//! Artifact-gated half: byte-identical loss trajectories across
//! `train.runtime ∈ {sequential, cluster}` ×
//! `train.shared_session ∈ {true, false}` (per-worker contexts may
//! never change the math), and a wall-clock timeline assertion that
//! with per-worker contexts at least two workers' forward executions
//! genuinely overlap, while the shared-session escape hatch serializes
//! them.

mod common;

use heta::config::RuntimeKind;
use heta::coordinator::SystemKind;
use heta::exec::ExecContext;

#[test]
fn exec_context_moves_to_worker_threads_without_locks() {
    // Compile-time: a worker thread takes its context by value/&mut —
    // if ExecContext ever grows non-Send state (shared client handles,
    // guards), this stops compiling.
    fn assert_send<T: Send>() {}
    assert_send::<ExecContext>();
    assert_send::<heta::exec::BatchArena>();
    assert_send::<heta::runtime::ParamSnapshot>();
}

#[test]
fn cluster_runtime_sources_are_lock_free() {
    // The acceptance criterion made mechanical: no mutex guards any
    // session or artifact execution in the cluster runtime — in fact no
    // lock type appears there at all. (Tests run with cwd = the package
    // root, so `src/cluster` resolves.)
    let mut scanned = 0;
    for entry in std::fs::read_dir("src/cluster").expect("src/cluster exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("rs") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        for forbidden in ["Mutex", "RwLock", "lock("] {
            assert!(
                !text.contains(forbidden),
                "{} contains '{forbidden}': the cluster runtime must stay lock-free \
                 (per-worker ExecContexts own execution; the KV-store RwLock lives in \
                 the session, the shared_session gate in the exec layer)",
                path.display()
            );
        }
        scanned += 1;
    }
    assert!(scanned >= 4, "expected to scan the cluster runtime sources");
}

#[test]
fn param_snapshots_are_immutable_under_later_steps() {
    use heta::optim::AdamParams;
    use heta::runtime::{InputSpec, ParamStore};
    let spec = InputSpec {
        kind: "weight".into(),
        shape: vec![4, 4],
        name: "W_test".into(),
        edge: -1,
        layer: 0,
        dtype: "f32".into(),
        init: "glorot".into(),
    };
    let mut store = ParamStore::new(11, AdamParams::default());
    store.ensure(&spec);
    let snap = store.snapshot();
    let frozen = snap.get("W_test").unwrap().to_vec();
    // Two optimizer steps while the snapshot is "in flight" on workers.
    store.step("W_test", &vec![0.5; 16]).unwrap();
    store.step("W_test", &vec![0.5; 16]).unwrap();
    assert_eq!(
        snap.get("W_test").unwrap(),
        frozen.as_slice(),
        "published snapshot mutated by a later step"
    );
    let snap2 = store.snapshot();
    assert!(snap2.version > snap.version);
    assert_ne!(snap2.get("W_test").unwrap(), frozen.as_slice());
}

// ---- artifact-gated: loss identity + wall-clock overlap ----

#[test]
fn losses_identical_across_runtimes_and_session_modes() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    for system in [SystemKind::Heta, SystemKind::DglOpt] {
        // 2×2: {sequential, cluster} × {shared, per-worker}. Sequential
        // ignores the flag (one thread is always serialized), but runs
        // both settings anyway — the flag may never leak into the math.
        common::assert_losses_identical(
            "mag-tiny",
            system,
            3,
            &[
                common::variant("sequential", |c| c.train.runtime = RuntimeKind::Sequential),
                common::variant("sequential+shared", |c| {
                    c.train.runtime = RuntimeKind::Sequential;
                    c.train.shared_session = true;
                }),
                common::variant("cluster", |c| c.train.runtime = RuntimeKind::Cluster),
                common::variant("cluster+shared", |c| {
                    c.train.runtime = RuntimeKind::Cluster;
                    c.train.shared_session = true;
                }),
            ],
        );
    }
}

#[test]
fn per_worker_contexts_overlap_forward_stages_in_wall_clock() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    // Per-worker contexts: across a whole epoch of batches, at least two
    // workers' forward executions must have run concurrently.
    let free = common::run_reports("mag-tiny", SystemKind::Heta, 1, "per-worker", |c| {
        c.train.runtime = RuntimeKind::Cluster;
    });
    let peak = free[0].wall.max_concurrent_forward();
    assert!(
        peak >= 2,
        "per-worker contexts never overlapped a forward stage (peak {peak})"
    );
    // The escape hatch serializes marshal+execute on one token, so no
    // two forward executions can ever be in flight together.
    let gated = common::run_reports("mag-tiny", SystemKind::Heta, 1, "shared-session", |c| {
        c.train.runtime = RuntimeKind::Cluster;
        c.train.shared_session = true;
    });
    let gated_peak = gated[0].wall.max_concurrent_forward();
    assert_eq!(
        gated_peak, 1,
        "shared_session must serialize forward executions (peak {gated_peak})"
    );
    // And the A/B may not change the math (also covered above, but this
    // pins the exact pair the overlap bench compares).
    assert_eq!(free[0].loss_mean, gated[0].loss_mean);
}
