//! Sampling determinism under concurrency, and sequential≡cluster
//! equivalence (Prop. 1 must be runtime-independent).
//!
//! The first half needs no AOT artifacts: it drives the cluster
//! transport (threads + mailbox collectives) through the same
//! per-partition sampling the RAF cluster workers perform — including
//! the double-buffered prefetch order — and asserts byte-identical
//! `TreeSample` ids against the sequential path across 3 epochs.
//!
//! The second half (artifact-gated) runs full training on both runtimes
//! through the shared `tests/common` equivalence harness and asserts
//! *identical* loss trajectories — not merely close: the cluster
//! collectives reduce in worker-id order, so float accumulation order
//! matches the sequential engine exactly. Divergence is reported at the
//! first differing batch index.

mod common;

use heta::cluster::collective::star;
use heta::config::{partition_edge_filter, Config, RuntimeKind};
use heta::coordinator::SystemKind;
use heta::hetgraph::NodeId;
use heta::partition::meta::meta_partition;
use heta::sampling::{sample_tree, TreeSample};
use heta::util::json::parse;
use heta::util::rng::Rng;

use common::variant;

const CFG: &str = r#"{
    "name": "determinism",
    "dataset": {"preset": "mag", "scale": 2e-4, "seed": 11},
    "model": {"arch": "rgcn", "hidden": 16, "fanouts": [4, 3]},
    "train": {"batch_size": 24, "num_partitions": 3, "seed": 5}
}"#;

/// Batch list exactly as the engines build it (shuffle + drop tail).
fn epoch_batches(cfg: &Config, g: &heta::hetgraph::HetGraph, epoch: usize) -> Vec<Vec<NodeId>> {
    let mut train = g.train_nodes();
    let mut rng = Rng::new(cfg.train.shuffle_seed(epoch));
    rng.shuffle(&mut train);
    train
        .chunks(cfg.train.batch_size)
        .filter(|c| c.len() == cfg.train.batch_size)
        .map(|c| c.to_vec())
        .collect()
}

#[test]
fn threaded_prefetching_workers_sample_identically_to_sequential() {
    let cfg = Config::from_json(&parse(CFG).unwrap()).unwrap();
    let g = std::sync::Arc::new(cfg.build_graph());
    let (mp, tree) = meta_partition(&g, cfg.train.num_partitions, cfg.model.layers, None);
    let tree = std::sync::Arc::new(tree);
    let parts = mp.num_parts;

    for epoch in 0..3 {
        let batches = epoch_batches(&cfg, &g, epoch);
        assert!(batches.len() >= 2, "need ≥2 batches to exercise prefetch");

        // Sequential reference: batch-major, partition-minor.
        let mut reference: Vec<Vec<TreeSample>> = Vec::new();
        for (bi, chunk) in batches.iter().enumerate() {
            let mut per_part = Vec::new();
            for p in 0..parts {
                let filter = partition_edge_filter(&tree, &mp, p);
                per_part.push(sample_tree(
                    &g,
                    &tree,
                    &cfg.model.fanouts,
                    chunk,
                    0,
                    cfg.train.batch_seed(epoch, bi),
                    filter,
                ));
            }
            reference.push(per_part);
        }

        // Cluster path: one thread per partition, sampling in the
        // runtime's double-buffered order (batch i+1 prefetched before
        // batch i's result ships), gathered in worker-id order.
        let (hub, ports) = star::<Vec<Vec<NodeId>>, ()>(parts);
        let gathered: Vec<Vec<Vec<Vec<NodeId>>>> = std::thread::scope(|s| {
            for port in ports {
                let cfg = &cfg;
                let g = &g;
                let tree = &tree;
                let mp = &mp;
                let batches = &batches;
                s.spawn(move || {
                    let p = port.id();
                    let mut prefetched: Option<TreeSample> = None;
                    for bi in 0..batches.len() {
                        let sample = prefetched.take().unwrap_or_else(|| {
                            let filter = partition_edge_filter(tree, mp, p);
                            sample_tree(
                                g,
                                tree,
                                &cfg.model.fanouts,
                                &batches[bi],
                                0,
                                cfg.train.batch_seed(epoch, bi),
                                filter,
                            )
                        });
                        // Prefetch the next batch before shipping this
                        // one — the pipeline's out-of-order schedule.
                        if bi + 1 < batches.len() {
                            let filter = partition_edge_filter(tree, mp, p);
                            prefetched = Some(sample_tree(
                                g,
                                tree,
                                &cfg.model.fanouts,
                                &batches[bi + 1],
                                0,
                                cfg.train.batch_seed(epoch, bi + 1),
                                filter,
                            ));
                        }
                        port.send(sample.ids).unwrap();
                        // Wait for the leader's release, like the
                        // runtime's Ready gate, so one gather round
                        // never sees two messages from one worker.
                        if bi + 1 < batches.len() {
                            port.recv().unwrap();
                        }
                    }
                });
            }
            (0..batches.len())
                .map(|bi| {
                    let round = hub.gather().unwrap();
                    if bi + 1 < batches.len() {
                        hub.broadcast(()).unwrap();
                    }
                    round
                })
                .collect()
        });

        for (bi, per_part) in gathered.iter().enumerate() {
            for (p, ids) in per_part.iter().enumerate() {
                assert_eq!(
                    ids, &reference[bi][p].ids,
                    "epoch {epoch} batch {bi} partition {p}: sampled tree diverged"
                );
            }
        }
    }
}

// ---- artifact-gated full-training equivalence (shared harness) ----

#[test]
fn cluster_runtime_reproduces_sequential_losses_exactly() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    for system in [SystemKind::Heta, SystemKind::DglMetis] {
        let reports = common::assert_losses_identical(
            "mag-tiny",
            system,
            3,
            &[
                variant("sequential", |c| c.train.runtime = RuntimeKind::Sequential),
                variant("cluster", |c| c.train.runtime = RuntimeKind::Cluster),
            ],
        );
        for (ep, r) in reports[1].iter().enumerate() {
            assert!(
                r.critical_path_s <= r.epoch_time_s,
                "{system:?} epoch {ep}: critical path {} exceeds summed time {}",
                r.critical_path_s,
                r.epoch_time_s
            );
        }
    }
}

#[test]
fn pipelined_critical_path_beats_sequential_runtime() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    let reports = common::assert_losses_identical(
        "mag-tiny",
        SystemKind::Heta,
        1,
        &[
            variant("sequential", |c| c.train.runtime = RuntimeKind::Sequential),
            variant("cluster", |c| c.train.runtime = RuntimeKind::Cluster),
        ],
    );
    let (seq, clu) = (&reports[0][0], &reports[1][0]);
    assert_eq!(
        seq.epoch_time_s, seq.critical_path_s,
        "sequential runtime has no overlap"
    );
    // Within one cluster run the summed and pipelined times price the
    // same event set, so the overlap saving is measurement-noise-free.
    assert!(
        clu.critical_path_s < clu.epoch_time_s,
        "pipeline hid no work: critical path {} vs summed {}",
        clu.critical_path_s,
        clu.epoch_time_s
    );
    assert!(
        clu.critical_path_s < seq.critical_path_s,
        "pipelined critical path {} not below sequential {}",
        clu.critical_path_s,
        seq.critical_path_s
    );
}
