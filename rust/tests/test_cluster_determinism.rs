//! Sampling determinism under concurrency, and sequential≡cluster
//! equivalence (Prop. 1 must be runtime-independent).
//!
//! The first half needs no AOT artifacts: it drives the cluster
//! transport (threads + mailbox collectives) through the same
//! per-partition sampling the RAF cluster workers perform — including
//! the double-buffered prefetch order — and asserts byte-identical
//! `TreeSample` ids against the sequential path across 3 epochs.
//!
//! The second half (artifact-gated, like `test_equivalence`) runs full
//! training on both runtimes and asserts *identical* loss trajectories
//! — not merely close: the cluster collectives reduce in worker-id
//! order, so float accumulation order matches the sequential engine
//! exactly.

use heta::cluster::collective::star;
use heta::config::{partition_edge_filter, Config, RuntimeKind};
use heta::coordinator::{Engine, Session, SystemKind};
use heta::hetgraph::NodeId;
use heta::partition::meta::meta_partition;
use heta::sampling::{sample_tree, TreeSample};
use heta::util::json::parse;
use heta::util::rng::Rng;

const CFG: &str = r#"{
    "name": "determinism",
    "dataset": {"preset": "mag", "scale": 2e-4, "seed": 11},
    "model": {"arch": "rgcn", "hidden": 16, "fanouts": [4, 3]},
    "train": {"batch_size": 24, "num_partitions": 3, "seed": 5}
}"#;

/// Batch list exactly as the engines build it (shuffle + drop tail).
fn epoch_batches(cfg: &Config, g: &heta::hetgraph::HetGraph, epoch: usize) -> Vec<Vec<NodeId>> {
    let mut train = g.train_nodes();
    let mut rng = Rng::new(cfg.train.shuffle_seed(epoch));
    rng.shuffle(&mut train);
    train
        .chunks(cfg.train.batch_size)
        .filter(|c| c.len() == cfg.train.batch_size)
        .map(|c| c.to_vec())
        .collect()
}

#[test]
fn threaded_prefetching_workers_sample_identically_to_sequential() {
    let cfg = Config::from_json(&parse(CFG).unwrap()).unwrap();
    let g = std::sync::Arc::new(cfg.build_graph());
    let (mp, tree) = meta_partition(&g, cfg.train.num_partitions, cfg.model.layers, None);
    let tree = std::sync::Arc::new(tree);
    let parts = mp.num_parts;

    for epoch in 0..3 {
        let batches = epoch_batches(&cfg, &g, epoch);
        assert!(batches.len() >= 2, "need ≥2 batches to exercise prefetch");

        // Sequential reference: batch-major, partition-minor.
        let mut reference: Vec<Vec<TreeSample>> = Vec::new();
        for (bi, chunk) in batches.iter().enumerate() {
            let mut per_part = Vec::new();
            for p in 0..parts {
                let filter = partition_edge_filter(&tree, &mp, p);
                per_part.push(sample_tree(
                    &g,
                    &tree,
                    &cfg.model.fanouts,
                    chunk,
                    0,
                    cfg.train.batch_seed(epoch, bi),
                    filter,
                ));
            }
            reference.push(per_part);
        }

        // Cluster path: one thread per partition, sampling in the
        // runtime's double-buffered order (batch i+1 prefetched before
        // batch i's result ships), gathered in worker-id order.
        let (hub, ports) = star::<Vec<Vec<NodeId>>, ()>(parts);
        let gathered: Vec<Vec<Vec<Vec<NodeId>>>> = std::thread::scope(|s| {
            for port in ports {
                let cfg = &cfg;
                let g = &g;
                let tree = &tree;
                let mp = &mp;
                let batches = &batches;
                s.spawn(move || {
                    let p = port.id();
                    let mut prefetched: Option<TreeSample> = None;
                    for bi in 0..batches.len() {
                        let sample = prefetched.take().unwrap_or_else(|| {
                            let filter = partition_edge_filter(tree, mp, p);
                            sample_tree(
                                g,
                                tree,
                                &cfg.model.fanouts,
                                &batches[bi],
                                0,
                                cfg.train.batch_seed(epoch, bi),
                                filter,
                            )
                        });
                        // Prefetch the next batch before shipping this
                        // one — the pipeline's out-of-order schedule.
                        if bi + 1 < batches.len() {
                            let filter = partition_edge_filter(tree, mp, p);
                            prefetched = Some(sample_tree(
                                g,
                                tree,
                                &cfg.model.fanouts,
                                &batches[bi + 1],
                                0,
                                cfg.train.batch_seed(epoch, bi + 1),
                                filter,
                            ));
                        }
                        port.send(sample.ids).unwrap();
                        // Wait for the leader's release, like the
                        // runtime's Ready gate, so one gather round
                        // never sees two messages from one worker.
                        if bi + 1 < batches.len() {
                            port.recv().unwrap();
                        }
                    }
                });
            }
            (0..batches.len())
                .map(|bi| {
                    let round = hub.gather().unwrap();
                    if bi + 1 < batches.len() {
                        hub.broadcast(()).unwrap();
                    }
                    round
                })
                .collect()
        });

        for (bi, per_part) in gathered.iter().enumerate() {
            for (p, ids) in per_part.iter().enumerate() {
                assert_eq!(
                    ids, &reference[bi][p].ids,
                    "epoch {epoch} batch {bi} partition {p}: sampled tree diverged"
                );
            }
        }
    }
}

// ---- artifact-gated full-training equivalence ----

fn run_with_runtime(
    system: SystemKind,
    cfg_name: &str,
    runtime: RuntimeKind,
    epochs: usize,
) -> Vec<(f64, f64, f64, f64)> {
    let mut cfg = Config::load(&format!("configs/{cfg_name}.json")).unwrap();
    cfg.train.runtime = runtime;
    let dir = format!("artifacts/{cfg_name}");
    let mut sess = Session::new(&cfg, &dir).unwrap();
    let mut engine = Engine::build(&mut sess, system).unwrap();
    (0..epochs)
        .map(|ep| {
            let r = engine.run_epoch(&mut sess, ep).unwrap();
            (r.loss_mean, r.accuracy, r.epoch_time_s, r.critical_path_s)
        })
        .collect()
}

#[test]
fn cluster_runtime_reproduces_sequential_losses_exactly() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    for system in [SystemKind::Heta, SystemKind::DglMetis] {
        let seq = run_with_runtime(system, "mag-tiny", RuntimeKind::Sequential, 3);
        let clu = run_with_runtime(system, "mag-tiny", RuntimeKind::Cluster, 3);
        for (ep, ((ls, acc_s, _, _), (lc, acc_c, et, cp))) in seq.iter().zip(&clu).enumerate() {
            assert_eq!(
                ls, lc,
                "{system:?} epoch {ep}: cluster loss {lc} != sequential {ls}"
            );
            assert_eq!(acc_s, acc_c, "{system:?} epoch {ep}: accuracy diverged");
            assert!(
                cp <= et,
                "{system:?} epoch {ep}: critical path {cp} exceeds summed time {et}"
            );
        }
    }
}

#[test]
fn pipelined_critical_path_beats_sequential_runtime() {
    if !heta::util::artifacts_ready("mag-tiny") {
        return;
    }
    let seq = run_with_runtime(SystemKind::Heta, "mag-tiny", RuntimeKind::Sequential, 1);
    let clu = run_with_runtime(SystemKind::Heta, "mag-tiny", RuntimeKind::Cluster, 1);
    let (_, _, seq_time, seq_cp) = seq[0];
    let (_, _, clu_time, clu_cp) = clu[0];
    assert_eq!(seq_time, seq_cp, "sequential runtime has no overlap");
    // Within one cluster run the summed and pipelined times price the
    // same event set, so the overlap saving is measurement-noise-free.
    assert!(
        clu_cp < clu_time,
        "pipeline hid no work: critical path {clu_cp} vs summed {clu_time}"
    );
    assert!(
        clu_cp < seq_cp,
        "pipelined critical path {clu_cp} not below sequential {seq_cp}"
    );
}
