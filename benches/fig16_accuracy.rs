//! Figure 16 — model-accuracy equivalence: Heta's RAF engine and the
//! vanilla (DGL) engine produce the same loss/accuracy trajectory
//! (Prop. 1 made empirical). Trains R-GAT on the ogbn-mag-shaped dataset
//! and HGT on the MAG240M-shaped dataset under both engines and prints
//! the paired curves.

use heta::config::Config;
use heta::coordinator::{Engine, Session, SystemKind};
use heta::util::bench::{report, table};

fn curves(cfg_name: &str, epochs: usize) {
    let cfg = Config::load(&format!("configs/{cfg_name}.json")).unwrap();
    let dir = format!("artifacts/{cfg_name}");
    let mut s_raf = Session::new(&cfg, &dir).unwrap();
    let mut raf = Engine::build(&mut s_raf, SystemKind::Heta).unwrap();
    let mut s_van = Session::new(&cfg, &dir).unwrap();
    let mut van = Engine::build(&mut s_van, SystemKind::DglMetis).unwrap();

    let mut rows = Vec::new();
    let mut max_div = 0.0f64;
    for ep in 0..epochs {
        let r = raf.run_epoch(&mut s_raf, ep).unwrap();
        let v = van.run_epoch(&mut s_van, ep).unwrap();
        max_div = max_div.max((r.loss_mean - v.loss_mean).abs());
        rows.push(vec![
            ep.to_string(),
            format!("{:.4}", r.loss_mean),
            format!("{:.4}", v.loss_mean),
            format!("{:.3}", r.accuracy),
            format!("{:.3}", v.accuracy),
        ]);
    }
    table(
        &format!("Fig 16 ({cfg_name}): Heta vs DGL accuracy curves"),
        &["epoch", "Heta loss", "DGL loss", "Heta acc", "DGL acc"],
        &rows,
    );
    report(
        &format!("fig16/{cfg_name}/max_loss_divergence"),
        format!("{max_div:.2e}"),
    );
}

fn main() {
    curves("mag-bench-rgat", 6);
    curves("mag240m-bench-hgt", 6);
}
