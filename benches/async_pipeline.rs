//! The async 1F1B window A/B (the PR-4 tentpole bench): for both
//! engines on the cluster runtime, run the same config at
//! `train.staleness` 0, 1 and 2 and compare the **critical-path epoch
//! time** (the overlap-aware modeled schedule: synchronous double-
//! buffered pipeline at 0, bounded-staleness 1F1B beyond). Also
//! reports the real wall epoch, the loss drift a window introduces vs
//! the synchronous trajectory (bounded staleness legitimately changes
//! the math — the drift is the price of the speedup and belongs in the
//! record), and the wall-clock overlap witnesses. Asserts staleness 1
//! strictly beats staleness 0 on critical path for both engines and
//! emits `BENCH_async.json` (uploaded by CI next to `BENCH_exec.json`).

use std::time::Instant;

use heta::config::{Config, RuntimeKind};
use heta::coordinator::{Engine, Session, SystemKind};
use heta::metrics::EpochReport;
use heta::util::bench::{report, table};
use heta::util::fmt_secs;
use heta::util::json::Json;

const EPOCHS: usize = 3;

/// Run `EPOCHS` cluster epochs at the given staleness; returns the
/// per-epoch reports plus the real wall seconds of the whole run.
fn run(cfg: &Config, system: SystemKind, staleness: usize) -> (Vec<EpochReport>, f64) {
    let mut cfg = cfg.clone();
    cfg.train.runtime = RuntimeKind::Cluster;
    cfg.train.staleness = staleness;
    let dir = format!("artifacts/{}", cfg.name);
    let mut sess = Session::new(&cfg, &dir)
        .unwrap_or_else(|e| panic!("session for {}: {e} (run `make artifacts`)", cfg.name));
    let mut engine = Engine::build(&mut sess, system).unwrap();
    let t0 = Instant::now();
    let reps = (0..EPOCHS)
        .map(|ep| engine.run_epoch(&mut sess, ep).unwrap())
        .collect();
    (reps, t0.elapsed().as_secs_f64())
}

fn critical_sum(reps: &[EpochReport]) -> f64 {
    reps.iter().map(|r| r.critical_path_s).sum()
}

fn main() {
    let cfg_name = "mag-bench";
    if !heta::util::artifacts_ready(cfg_name) {
        return;
    }
    let cfg = Config::load(&format!("configs/{cfg_name}.json"))
        .unwrap_or_else(|e| panic!("loading config {cfg_name}: {e}"));

    let mut rows = Vec::new();
    let mut entries = Vec::new();
    for (system, label) in [(SystemKind::Heta, "raf"), (SystemKind::DglMetis, "vanilla")] {
        let runs: Vec<(usize, Vec<EpochReport>, f64)> = [0usize, 1, 2]
            .into_iter()
            .map(|k| {
                let (reps, wall) = run(&cfg, system, k);
                (k, reps, wall)
            })
            .collect();
        let sync_critical = critical_sum(&runs[0].1);
        let sync_loss = runs[0].1.last().map(|r| r.loss_mean).unwrap_or(f64::NAN);
        for (k, reps, wall) in &runs {
            let critical = critical_sum(reps);
            let loss = reps.last().map(|r| r.loss_mean).unwrap_or(f64::NAN);
            let bwd_fwd: usize = reps
                .iter()
                .map(|r| r.wall.backward_overlapping_later_forward())
                .sum();
            let cross: usize = reps.iter().map(|r| r.wall.cross_batch_forward_overlap()).sum();
            rows.push(vec![
                label.to_string(),
                format!("{k}"),
                fmt_secs(critical / EPOCHS as f64),
                format!("{:.3}x", sync_critical / critical.max(1e-12)),
                fmt_secs(*wall),
                format!("{:+.2e}", loss - sync_loss),
                format!("{bwd_fwd}/{cross}"),
            ]);
            entries.push(Json::from_pairs(vec![
                ("engine", Json::str(label)),
                ("config", Json::str(cfg_name)),
                ("staleness", Json::num(*k as f64)),
                ("epochs", Json::num(EPOCHS as f64)),
                ("critical_path_s", Json::num(critical / EPOCHS as f64)),
                ("speedup_vs_sync", Json::num(sync_critical / critical.max(1e-12))),
                ("wall_s", Json::num(*wall)),
                ("final_loss", Json::num(loss)),
                ("loss_drift_vs_sync", Json::num(loss - sync_loss)),
                ("bwd_fwd_overlaps", Json::num(bwd_fwd as f64)),
                ("cross_batch_fwd_overlaps", Json::num(cross as f64)),
            ]));
        }
        let k1_critical = critical_sum(&runs[1].1);
        assert!(
            k1_critical < sync_critical,
            "{label}: staleness=1 critical path {k1_critical} not strictly below \
             staleness=0 {sync_critical}"
        );
        report(
            &format!("async/{label}/critical_speedup_k1"),
            format!("{:.3}x", sync_critical / k1_critical.max(1e-12)),
        );
        report(
            &format!("async/{label}/critical_speedup_k2"),
            format!("{:.3}x", sync_critical / critical_sum(&runs[2].1).max(1e-12)),
        );
    }
    table(
        "Async 1F1B window: critical-path epoch time vs staleness, cluster runtime",
        &[
            "engine",
            "staleness",
            "critical/epoch",
            "speedup",
            "wall total",
            "loss drift",
            "bwd||fwd / x-batch",
        ],
        &rows,
    );

    let out = Json::from_pairs(vec![("async_pipeline", Json::Arr(entries))]).to_string();
    std::fs::write("BENCH_async.json", &out).expect("write BENCH_async.json");
    println!("wrote BENCH_async.json");
}
