//! Figure 13 — hidden-dimension ablation (Heta's partial-aggregation
//! traffic grows with H but stays ahead of DGL-Opt);
//! Figure 14 — scalability in machines/GPUs (Heta's communication is
//! constant; baselines grow with partition count);
//! Figure 15 — sampling-fanout ablation (Heta's traffic is fanout-
//! independent; 3-hop point reported analytically — the 2-layer model
//! family is compiled AOT, see EXPERIMENTS.md).

use heta::comm::CostModel;
use heta::config::Config;
use heta::coordinator::{bench_run, Engine, Session, SystemKind};
use heta::datagen::{generate, GenParams, Preset};
use heta::hetgraph::MetaTree;
use heta::partition::edgecut;
use heta::sampling::{remote_counts, sample_tree};
use heta::util::bench::{report, table};
use heta::util::{fmt_bytes, fmt_secs};

fn fig13() {
    let mut rows = Vec::new();
    for cfg_name in ["mag-bench", "mag-bench-h64", "mag-bench-h128"] {
        let hidden = Config::load(&format!("configs/{cfg_name}.json"))
            .unwrap()
            .model
            .hidden;
        for sys in [SystemKind::Heta, SystemKind::DglOpt] {
            let (rep, _) = bench_run(cfg_name, sys, 1);
            rows.push(vec![
                hidden.to_string(),
                sys.name().into(),
                fmt_secs(rep.epoch_time_s),
                fmt_bytes(rep.comm.bytes[0]),
            ]);
        }
    }
    table(
        "Fig 13: hidden-dimension ablation (ogbn-mag R-GCN)",
        &["hidden", "system", "epoch time", "net bytes"],
        &rows,
    );
}

fn fig14() {
    let mut rows = Vec::new();
    for parts in [2usize, 3, 4] {
        for sys in [SystemKind::Heta, SystemKind::DglOpt, SystemKind::GraphLearn] {
            let mut cfg = Config::load("configs/donor-bench-rgat.json").unwrap();
            cfg.train.num_partitions = parts;
            // The AOT artifact set is compiled for the plan's partition
            // count; for the sweep we rebuild sessions only when the
            // artifact set exists (2 partitions) and report comm-model
            // numbers otherwise.
            if parts == 2 {
                let mut sess =
                    Session::new(&cfg, "artifacts/donor-bench-rgat").unwrap();
                let mut eng = Engine::build(&mut sess, sys).unwrap();
                let rep = eng.run_epoch(&mut sess, 0).unwrap();
                rows.push(vec![
                    format!("{parts} machines ({} GPUs)", parts * 8),
                    sys.name().into(),
                    fmt_secs(rep.epoch_time_s),
                    fmt_bytes(rep.comm.bytes[0]),
                ]);
            } else {
                // Analytic communication at higher machine counts.
                let g = cfg.build_graph();
                let tree = MetaTree::build(&g.schema, 2);
                let b = cfg.train.batch_size;
                let batch: Vec<u32> = g.train_nodes()[..b.min(g.train_nodes().len())].to_vec();
                let bytes = match sys {
                    SystemKind::Heta => {
                        // 2 layers × (partials + grads) × [B,H] per extra worker
                        (parts as u64 - 1) * 2 * 2 * (batch.len() * cfg.model.hidden * 4) as u64
                    }
                    _ => {
                        let part = edgecut::random(&g, parts, 1);
                        let sample =
                            sample_tree(&g, &tree, &cfg.model.fanouts, &batch, 0, 7, |_| true);
                        let r = remote_counts(&tree, &sample, &part, 0);
                        // remote features ×dim×4, summed over workers ≈ ×parts
                        r.remote * 4 * 64 * parts as u64
                    }
                };
                rows.push(vec![
                    format!("{parts} machines ({} GPUs)", parts * 8),
                    sys.name().into(),
                    "(analytic)".into(),
                    fmt_bytes(bytes),
                ]);
            }
        }
    }
    table(
        "Fig 14: scalability (Donor R-GAT); Heta comm constant per batch",
        &["cluster", "system", "epoch time", "net bytes/batch-ish"],
        &rows,
    );
}

fn fig15() {
    // Fanout sweep on IGB-HET: Heta's cross-partition traffic is
    // constant; the vanilla engines' remote feature volume grows with
    // the sampled neighborhood.
    let g = generate(Preset::IgbHet, 2e-5, &GenParams::default());
    let tree = MetaTree::build(&g.schema, 2);
    let part = edgecut::random(&g, 2, 1);
    let b = 64usize;
    let batch: Vec<u32> = g.train_nodes()[..b].to_vec();
    let hidden = 32u64;
    let mut rows = Vec::new();
    for fanouts in [[4usize, 3], [10, 5], [25, 20]] {
        let sample = sample_tree(&g, &tree, &fanouts, &batch, 0, 7, |_| true);
        let r = remote_counts(&tree, &sample, &part, 0);
        let feat_bytes = r.remote * 1024 * 4; // IGB dims are uniform 1024
        let heta_bytes = 2 * 2 * (b as u64) * hidden * 4;
        rows.push(vec![
            format!("{{{},{}}}", fanouts[0], fanouts[1]),
            fmt_bytes(feat_bytes),
            fmt_bytes(heta_bytes),
            format!("{:.0}x", feat_bytes as f64 / heta_bytes as f64),
        ]);
    }
    table(
        "Fig 15: per-batch remote traffic vs fanout (IGB-HET, 2 partitions)",
        &["fanout", "vanilla remote-feature bytes", "Heta partial bytes", "ratio"],
        &rows,
    );
    // Measured 2-hop end-to-end points at the default fanout.
    for sys in [SystemKind::Heta, SystemKind::DglOpt] {
        let (rep, _) = bench_run("igb-bench", sys, 1);
        report(
            &format!("fig15/epoch_time/{}", sys.name()),
            fmt_secs(rep.epoch_time_s),
        );
    }
    let _ = CostModel::default();
}

fn main() {
    fig13();
    fig14();
    fig15();
}
