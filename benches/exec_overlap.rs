//! Per-worker execution contexts vs the shared-session escape hatch
//! (the PR-3 tentpole A/B): for both engines on the cluster runtime,
//! measure the **real wall-clock** epoch duration and the modeled
//! critical path with `train.shared_session` on (every marshal+execute
//! serialized on one token — the PR-1 behavior) and off (each worker
//! executes on its own PJRT client). Reports the peak number of
//! concurrent forward executions as the overlap evidence, asserts the
//! losses are byte-identical, and emits `BENCH_exec.json` (uploaded by
//! CI next to `BENCH_gather.json`).

use std::time::Instant;

use heta::config::{Config, RuntimeKind};
use heta::coordinator::{Engine, Session, SystemKind};
use heta::metrics::EpochReport;
use heta::util::bench::{report, table};
use heta::util::fmt_secs;
use heta::util::json::Json;

/// One cluster epoch; returns the report plus the real wall seconds.
fn run(cfg: &Config, system: SystemKind, shared_session: bool) -> (EpochReport, f64) {
    let mut cfg = cfg.clone();
    cfg.train.runtime = RuntimeKind::Cluster;
    cfg.train.shared_session = shared_session;
    let dir = format!("artifacts/{}", cfg.name);
    let mut sess = Session::new(&cfg, &dir)
        .unwrap_or_else(|e| panic!("session for {}: {e} (run `make artifacts`)", cfg.name));
    let mut engine = Engine::build(&mut sess, system).unwrap();
    let t0 = Instant::now();
    let rep = engine.run_epoch(&mut sess, 0).unwrap();
    (rep, t0.elapsed().as_secs_f64())
}

fn main() {
    let cfg_name = "mag-bench";
    if !heta::util::artifacts_ready(cfg_name) {
        return;
    }
    let cfg = Config::load(&format!("configs/{cfg_name}.json"))
        .unwrap_or_else(|e| panic!("loading config {cfg_name}: {e}"));

    let mut rows = Vec::new();
    let mut entries = Vec::new();
    for (system, label) in [(SystemKind::Heta, "raf"), (SystemKind::DglMetis, "vanilla")] {
        let (shared, shared_wall) = run(&cfg, system, true);
        let (split, split_wall) = run(&cfg, system, false);
        assert_eq!(
            shared.loss_mean, split.loss_mean,
            "{label}: per-worker contexts changed the loss"
        );
        let peak_shared = shared.wall.max_concurrent_forward();
        let peak_split = split.wall.max_concurrent_forward();
        for (mode, rep, wall, peak) in [
            ("shared-session", &shared, shared_wall, peak_shared),
            ("per-worker", &split, split_wall, peak_split),
        ] {
            rows.push(vec![
                label.to_string(),
                mode.to_string(),
                fmt_secs(wall),
                fmt_secs(rep.critical_path_s),
                format!("{peak}"),
            ]);
        }
        report(
            &format!("exec/{label}/wall_speedup"),
            format!("{:.2}x", shared_wall / split_wall.max(1e-12)),
        );
        report(&format!("exec/{label}/peak_concurrent_forward"), peak_split);
        entries.push(Json::from_pairs(vec![
            ("engine", Json::str(label)),
            ("config", Json::str(cfg_name)),
            ("shared_wall_s", Json::num(shared_wall)),
            ("per_worker_wall_s", Json::num(split_wall)),
            ("wall_speedup", Json::num(shared_wall / split_wall.max(1e-12))),
            ("shared_critical_path_s", Json::num(shared.critical_path_s)),
            ("per_worker_critical_path_s", Json::num(split.critical_path_s)),
            ("peak_concurrent_forward_shared", Json::num(peak_shared as f64)),
            ("peak_concurrent_forward", Json::num(peak_split as f64)),
            ("loss_identical", Json::Bool(shared.loss_mean == split.loss_mean)),
        ]));
    }
    table(
        "Exec contexts: shared session vs per-worker, cluster runtime",
        &["engine", "mode", "wall epoch", "critical path", "peak fwd||"],
        &rows,
    );

    let out = Json::from_pairs(vec![("exec_overlap", Json::Arr(entries))]).to_string();
    std::fs::write("BENCH_exec.json", &out).expect("write BENCH_exec.json");
    println!("wrote BENCH_exec.json");
}
