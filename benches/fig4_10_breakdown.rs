//! Figure 4 — % of epoch time per training stage for the vanilla (DGL)
//! execution model on Freebase / ogbn-mag / MAG240M (the motivation:
//! learnable-feature updates are 24–35% of epoch time), and
//! Figure 10 — stage breakdown of Heta vs baselines on IGB-HET and
//! MAG240M (Heta eliminates cross-machine work in sample/fetch/update).

use heta::coordinator::{bench_run, SystemKind};
use heta::metrics::STAGES;
use heta::util::bench::table;

fn breakdown_row(label: &str, cfg: &str, sys: SystemKind) -> Vec<String> {
    let (rep, _) = bench_run(cfg, sys, 1);
    let mut row = vec![label.to_string(), sys.name().to_string()];
    for (_, pct) in rep.stages.percentages() {
        row.push(format!("{pct:.1}%"));
    }
    row
}

fn main() {
    let header: Vec<&str> = ["workload", "system"]
        .into_iter()
        .chain(STAGES.iter().map(|s| s.name()))
        .collect();

    // Fig. 4: vanilla DGL breakdown — update stage must be a major
    // fraction on learnable-feature datasets (Freebase, MAG240M).
    let rows4 = vec![
        breakdown_row("Freebase", "freebase-bench", SystemKind::DglMetis),
        breakdown_row("ogbn-mag", "mag-bench", SystemKind::DglMetis),
        breakdown_row("MAG240M", "mag240m-bench", SystemKind::DglMetis),
    ];
    table("Fig 4: vanilla (DGL-METIS) stage breakdown", &header, &rows4);

    // Fig. 10: Heta vs baselines on the large datasets.
    let mut rows10 = Vec::new();
    for cfg in ["igb-bench", "mag240m-bench"] {
        for sys in [
            SystemKind::Heta,
            SystemKind::DglMetis,
            SystemKind::DglOpt,
            SystemKind::GraphLearn,
        ] {
            // GraphLearn unsupported on MAG240M (learnable features).
            if cfg == "mag240m-bench" && sys == SystemKind::GraphLearn {
                continue;
            }
            rows10.push(breakdown_row(cfg, cfg, sys));
        }
    }
    table("Fig 10: R-GCN stage breakdown, Heta vs baselines", &header, &rows10);
}
