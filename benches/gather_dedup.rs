//! Deduplicated-frontier gather A/B (the PR-2 tentpole): per-slot
//! gather + per-occurrence cache accounting (seed path, `dedup_fetch =
//! false`) vs frontier staging + in-memory scatter + batched cache
//! accounting (`dedup_fetch = true`).
//!
//! The artifact-free half measures the input-build mechanics directly —
//! rows fetched, bytes moved, wall-clock per input build — on a sampled
//! Mag tree. The artifact-gated half (skipped without `make artifacts`)
//! runs full epochs on both engines and both runtimes with the flag on
//! and off, asserting byte-identical losses and strictly fewer fetched
//! rows/bytes. Always emits `BENCH_gather.json`.

use heta::cache::{FeatureCache, Policy, TypeProfile};
use heta::comm::CostModel;
use heta::config::{Config, RuntimeKind};
use heta::coordinator::{Engine, Session, SystemKind};
use heta::datagen::{generate, GenParams, Preset};
use heta::hetgraph::{HetGraph, MetaTree};
use heta::kvstore::{scatter_rows, FeatureStore, FetchStats};
use heta::metrics::EpochReport;
use heta::sampling::{presample_hotness, sample_tree, Frontier, TreeSample, PAD};
use heta::util::bench::{black_box, report, table, Bench};
use heta::util::json::Json;

/// Seed-path input build: every padded slot of every block input
/// gathered independently, cache consulted per occurrence.
#[allow(clippy::too_many_arguments)]
fn build_slots(
    g: &HetGraph,
    tree: &MetaTree,
    store: &FeatureStore,
    sample: &TreeSample,
    batch: &[u32],
    cache: &mut FeatureCache,
    cost: &CostModel,
    bufs: &mut Vec<Vec<f32>>,
) -> (FetchStats, f64) {
    let mut stats = FetchStats::default();
    let mut cache_t = 0.0;
    for (ei, e) in tree.edges.iter().enumerate() {
        let ty = tree.vertices[e.child].ty;
        let ids = &sample.ids[e.child];
        let dim = store.dim(ty);
        let buf = &mut bufs[ei];
        buf.resize(ids.len() * dim, 0.0);
        stats.merge(store.gather(ty, ids, buf, |_| false).unwrap());
        for &id in ids.iter().filter(|&&id| id != PAD) {
            cache_t += cache.access(cost, ty, id, 0, false);
        }
    }
    // Target features of the root batch.
    let tgt = g.schema.target;
    let dim = store.dim(tgt);
    let buf = bufs.last_mut().unwrap();
    buf.resize(batch.len() * dim, 0.0);
    stats.merge(store.gather(tgt, batch, buf, |_| false).unwrap());
    for &id in batch {
        cache_t += cache.access(cost, tgt, id, 0, false);
    }
    (stats, cache_t)
}

/// Dedup-path input build: frontier rebuild, one unique-row staging
/// gather + one batched cache consultation per type, scatter per input.
#[allow(clippy::too_many_arguments)]
fn build_dedup(
    g: &HetGraph,
    tree: &MetaTree,
    store: &FeatureStore,
    sample: &TreeSample,
    batch: &[u32],
    cache: &mut FeatureCache,
    cost: &CostModel,
    fr: &mut Frontier,
    staging: &mut Vec<Vec<f32>>,
    bufs: &mut Vec<Vec<f32>>,
) -> (FetchStats, f64) {
    let ntypes = g.schema.node_types.len();
    fr.rebuild(tree, sample, ntypes, true);
    let mut stats = FetchStats::default();
    let mut cache_t = 0.0;
    for ty in 0..ntypes {
        let uniq = fr.rows(ty);
        let dim = store.dim(ty);
        staging[ty].resize(uniq.len() * dim, 0.0);
        stats.merge(store.gather_unique(ty, uniq, &mut staging[ty], |_| false).unwrap());
        cache_t += cache.access_unique(cost, ty, uniq, 0);
    }
    for (ei, e) in tree.edges.iter().enumerate() {
        let ty = tree.vertices[e.child].ty;
        let dim = store.dim(ty);
        let inv = &fr.slot_to_unique[e.child];
        let buf = &mut bufs[ei];
        buf.resize(inv.len() * dim, 0.0);
        scatter_rows(&staging[ty], inv, dim, buf);
    }
    let tgt = g.schema.target;
    let dim = store.dim(tgt);
    let buf = bufs.last_mut().unwrap();
    buf.resize(batch.len() * dim, 0.0);
    for (i, &id) in batch.iter().enumerate() {
        let u = fr.unique_index(tgt, id).expect("root batch is in the frontier");
        buf[i * dim..(i + 1) * dim].copy_from_slice(&staging[tgt][u * dim..(u + 1) * dim]);
    }
    (stats, cache_t)
}

fn engine_epoch(cfg: &Config, system: SystemKind, runtime: RuntimeKind, dedup: bool) -> EpochReport {
    let mut cfg = cfg.clone();
    cfg.train.runtime = runtime;
    cfg.train.dedup_fetch = dedup;
    let dir = format!("artifacts/{}", cfg.name);
    let mut sess = Session::new(&cfg, &dir)
        .unwrap_or_else(|e| panic!("session for {}: {e} (run `make artifacts`)", cfg.name));
    let mut engine = Engine::build(&mut sess, system).unwrap();
    engine.run_epoch(&mut sess, 0).unwrap()
}

fn main() {
    let b = Bench::new("gather_dedup").with_budget(1.5);
    let g = generate(Preset::Mag, 1e-3, &GenParams::default());
    let tree = MetaTree::build(&g.schema, 2);
    let fanouts = [10usize, 5];
    let batch: Vec<u32> = g.train_nodes()[..64].to_vec();
    let sample = sample_tree(&g, &tree, &fanouts, &batch, 0, 7, |_| true);
    let store = FeatureStore::new(&g, 1);
    let cost = CostModel::default();
    let hotness = presample_hotness(&g, &tree, &fanouts, 64, 1, 3);
    let profiles: Vec<TypeProfile> = g
        .schema
        .node_types
        .iter()
        .map(|t| TypeProfile {
            name: t.name.clone(),
            count: t.count,
            feat_dim: t.feat_dim,
            learnable: t.learnable,
        })
        .collect();
    let mut cache =
        FeatureCache::build(Policy::HotnessMissPenalty, &profiles, &hotness, &cost, 4 << 20, 1);

    let nbufs = tree.edges.len() + 1;
    let mut bufs: Vec<Vec<f32>> = vec![Vec::new(); nbufs];
    let mut staging: Vec<Vec<f32>> = vec![Vec::new(); g.schema.node_types.len()];
    let mut fr = Frontier::default();

    // One untimed pass of each to collect the accounting.
    let (slot_stats, _) =
        build_slots(&g, &tree, &store, &sample, &batch, &mut cache, &cost, &mut bufs);
    let (uniq_stats, _) = build_dedup(
        &g, &tree, &store, &sample, &batch, &mut cache, &cost, &mut fr, &mut staging, &mut bufs,
    );
    assert!(uniq_stats.rows < slot_stats.rows, "dedup must fetch fewer rows");
    assert!(uniq_stats.bytes < slot_stats.bytes, "dedup must move fewer bytes");

    let r_slots = b.run("input_build/per_slot", || {
        black_box(build_slots(
            &g, &tree, &store, &sample, &batch, &mut cache, &cost, &mut bufs,
        ));
    });
    let r_dedup = b.run("input_build/frontier_dedup", || {
        black_box(build_dedup(
            &g, &tree, &store, &sample, &batch, &mut cache, &cost, &mut fr, &mut staging,
            &mut bufs,
        ));
    });

    report("gather/rows_per_slot", slot_stats.rows);
    report("gather/rows_unique", uniq_stats.rows);
    report("gather/bytes_per_slot", slot_stats.bytes);
    report("gather/bytes_unique", uniq_stats.bytes);
    let mut pairs = vec![
        ("rows_per_slot", Json::num(slot_stats.rows as f64)),
        ("rows_unique", Json::num(uniq_stats.rows as f64)),
        ("bytes_per_slot", Json::num(slot_stats.bytes as f64)),
        ("bytes_unique", Json::num(uniq_stats.bytes as f64)),
    ];
    if let (Some(rs), Some(rd)) = (&r_slots, &r_dedup) {
        report("gather/build_s_per_slot", format!("{:.9}", rs.mean_s));
        report("gather/build_s_dedup", format!("{:.9}", rd.mean_s));
        report("gather/build_speedup", format!("{:.2}x", rs.mean_s / rd.mean_s));
        pairs.push(("build_s_per_slot", Json::num(rs.mean_s)));
        pairs.push(("build_s_dedup", Json::num(rd.mean_s)));
        pairs.push(("build_speedup", Json::num(rs.mean_s / rd.mean_s)));
    }
    let micro = Json::from_pairs(pairs);

    // ---- artifact-gated engine A/B (sequential vs cluster) ----
    let cfg_name = "mag-bench";
    let engines = if heta::util::artifacts_ready(cfg_name) {
        let cfg = Config::load(&format!("configs/{cfg_name}.json"))
            .unwrap_or_else(|e| panic!("loading config {cfg_name}: {e}"));
        let mut rows = Vec::new();
        let mut entries = Vec::new();
        for (system, sname) in [(SystemKind::Heta, "raf"), (SystemKind::DglOpt, "vanilla")] {
            for (runtime, rname) in [
                (RuntimeKind::Sequential, "sequential"),
                (RuntimeKind::Cluster, "cluster"),
            ] {
                let on = engine_epoch(&cfg, system, runtime, true);
                let off = engine_epoch(&cfg, system, runtime, false);
                assert_eq!(
                    on.loss_mean, off.loss_mean,
                    "{sname}/{rname}: dedup_fetch must not change losses"
                );
                assert!(
                    on.fetch.rows < off.fetch.rows && on.fetch.bytes < off.fetch.bytes,
                    "{sname}/{rname}: dedup must strictly reduce fetched rows/bytes"
                );
                rows.push(vec![
                    format!("{sname}/{rname}"),
                    format!("{}", off.fetch.rows),
                    format!("{}", on.fetch.rows),
                    format!("{:.2}x", off.fetch.rows as f64 / on.fetch.rows.max(1) as f64),
                ]);
                entries.push((
                    format!("{sname}_{rname}"),
                    Json::from_pairs(vec![
                        ("rows_off", Json::num(off.fetch.rows as f64)),
                        ("rows_on", Json::num(on.fetch.rows as f64)),
                        ("bytes_off", Json::num(off.fetch.bytes as f64)),
                        ("bytes_on", Json::num(on.fetch.bytes as f64)),
                        ("loss", Json::num(on.loss_mean)),
                    ]),
                ));
            }
        }
        table(
            "Dedup gather: fetched rows per epoch (off vs on)",
            &["engine/runtime", "rows off", "rows on", "reduction"],
            &rows,
        );
        Some(Json::Obj(entries.into_iter().collect()))
    } else {
        println!("skipping engine A/B: artifacts/{cfg_name} missing (run `make artifacts`)");
        None
    };

    let mut top = vec![("micro", micro)];
    if let Some(e) = engines {
        top.push(("engines", e));
    }
    let out = Json::from_pairs(vec![("gather_dedup", Json::from_pairs(top))]).to_string();
    std::fs::write("BENCH_gather.json", &out).expect("write BENCH_gather.json");
    println!("wrote BENCH_gather.json");
}
