//! Figures 8 & 9 — overall epoch time of Heta vs DGL-Random / DGL-METIS
//! / DGL-Opt / GraphLearn across HGNN models and datasets. Epoch time is
//! the simulated-clock figure (measured PJRT compute + modeled data
//! movement; see DESIGN.md substitutions). The paper's shape to
//! reproduce: Heta fastest everywhere, 1.9–5.8× over DGL variants and
//! 1.5–2.3× over GraphLearn.

use heta::coordinator::{bench_run, SystemKind};
use heta::util::bench::table;
use heta::util::fmt_secs;

fn run_config(rows: &mut Vec<Vec<String>>, cfg: &str, label: &str, systems: &[SystemKind]) {
    let mut heta_time = f64::NAN;
    for &sys in systems {
        let (rep, _) = bench_run(cfg, sys, 1);
        if sys == SystemKind::Heta {
            heta_time = rep.epoch_time_s;
        }
        rows.push(vec![
            label.into(),
            sys.name().into(),
            fmt_secs(rep.epoch_time_s),
            if sys == SystemKind::Heta {
                "1.00x".into()
            } else {
                format!("{:.2}x", rep.epoch_time_s / heta_time)
            },
        ]);
    }
}

fn main() {
    let all = SystemKind::all();
    // GraphLearn does not support learnable features → skipped on
    // datasets with featureless types (paper §8.1); DGL-Opt needs node
    // features to cache → skipped on Freebase.
    let no_gl: Vec<SystemKind> = all
        .iter()
        .copied()
        .filter(|s| *s != SystemKind::GraphLearn)
        .collect();
    let fb: Vec<SystemKind> = no_gl
        .iter()
        .copied()
        .filter(|s| *s != SystemKind::DglOpt)
        .collect();

    let mut rows = Vec::new();
    // Fig. 8: medium datasets × three models.
    run_config(&mut rows, "mag-bench", "ogbn-mag/R-GCN", &no_gl);
    run_config(&mut rows, "mag-bench-rgat", "ogbn-mag/R-GAT", &no_gl);
    run_config(&mut rows, "mag-bench-hgt", "ogbn-mag/HGT", &no_gl);
    run_config(&mut rows, "freebase-bench", "Freebase/R-GCN", &fb);
    run_config(&mut rows, "donor-bench", "Donor/R-GCN", &all);
    run_config(&mut rows, "donor-bench-rgat", "Donor/R-GAT", &all);
    table(
        "Fig 8: epoch time, medium datasets (speedup vs Heta)",
        &["workload", "system", "epoch time", "time/Heta"],
        &rows,
    );

    // Fig. 9: large datasets.
    let mut rows9 = Vec::new();
    run_config(&mut rows9, "igb-bench", "IGB-HET/R-GCN", &all);
    run_config(&mut rows9, "igb-bench-rgat", "IGB-HET/R-GAT", &all);
    run_config(&mut rows9, "mag240m-bench", "MAG240M/R-GCN", &no_gl);
    run_config(&mut rows9, "mag240m-bench-hgt", "MAG240M/HGT", &no_gl);
    table(
        "Fig 9: epoch time, large datasets (speedup vs Heta)",
        &["workload", "system", "epoch time", "time/Heta"],
        &rows9,
    );
}
