//! Serving-mode A/B (the PR-9 tentpole): embedding reuse + frontier
//! dedup vs the no-reuse baseline, over the deadline-driven batcher.
//!
//! The artifact-free half measures the batcher mechanics alone —
//! stream generation and the close rule on a simulated service clock.
//! The artifact-gated half (skipped without `make artifacts`) serves a
//! deterministic 256-request Zipf stream through real forwards on
//! `mag-tiny` in three arms — reuse+dedup, no-reuse, and
//! no-reuse+no-dedup — asserting byte-identical served embeddings
//! across all arms and strictly fewer fetched rows per request with
//! reuse on. Always emits `BENCH_serve.json` with p50/p99 latency, QPS,
//! deadline misses, and the per-arm fetch ledger.

use heta::config::Config;
use heta::coordinator::SystemKind;
use heta::datagen::{generate, GenParams, Preset};
use heta::net::Backend;
use heta::serve::{batcher, run_serve, synthetic_stream, BatcherOpts, ServeOpts, StreamOpts};
use heta::util::bench::{black_box, report, table, Bench};
use heta::util::json::Json;

fn bench_opts() -> ServeOpts {
    ServeOpts {
        requests: 256,
        qps: 2000.0,
        deadline_ms: 250.0,
        zipf_alpha: 1.1,
        ..Default::default()
    }
}

fn main() {
    let b = Bench::new("serve").with_budget(1.5);

    // ---- artifact-free: stream generation + the close rule ----
    let g = generate(Preset::Mag, 1e-3, &GenParams::default());
    let stream_opts = StreamOpts {
        requests: 4096,
        qps: 20_000.0,
        deadline_ms: 10.0,
        zipf_alpha: 1.1,
        seed: 7,
    };
    let reqs = synthetic_stream(&g, &stream_opts).expect("synthetic stream");
    let r_stream = b.run("serve/stream_gen", || {
        black_box(synthetic_stream(&g, &stream_opts).unwrap());
    });
    let bopts = BatcherOpts { capacity: 64, service_bound_us: 2_000 };
    let r_batcher = b.run("serve/batcher_close_rule", || {
        black_box(batcher::run(&reqs, &bopts, |batch| Ok(batch.len() as u64 * 20)).unwrap());
    });
    let timeline =
        batcher::run(&reqs, &bopts, |batch| Ok(batch.len() as u64 * 20)).expect("batcher");
    report("serve/micro_batches", timeline.batches);
    report("serve/micro_misses", timeline.misses);
    let mut micro_pairs = vec![
        ("stream_requests", Json::num(reqs.len() as f64)),
        ("batches", Json::num(timeline.batches as f64)),
        ("misses", Json::num(timeline.misses as f64)),
        ("max_batch", Json::num(timeline.max_batch as f64)),
    ];
    if let (Some(rs), Some(rb)) = (&r_stream, &r_batcher) {
        report("serve/stream_gen_s", format!("{:.9}", rs.mean_s));
        report("serve/batcher_s", format!("{:.9}", rb.mean_s));
        micro_pairs.push(("stream_gen_s", Json::num(rs.mean_s)));
        micro_pairs.push(("batcher_s", Json::num(rb.mean_s)));
    }
    let micro = Json::from_pairs(micro_pairs);

    // ---- artifact-gated: real forwards, reuse/dedup A/B ----
    let cfg_name = "mag-tiny";
    let arms = if heta::util::artifacts_ready(cfg_name) {
        let cfg = Config::load(&format!("configs/{cfg_name}.json"))
            .unwrap_or_else(|e| panic!("loading config {cfg_name}: {e}"));
        let dir = format!("artifacts/{cfg_name}");
        let base = bench_opts();
        let arms = [
            ("reuse_dedup", ServeOpts { ..base.clone() }),
            ("no_reuse", ServeOpts { reuse: false, ..base.clone() }),
            ("no_reuse_no_dedup", ServeOpts { reuse: false, dedup_fetch: false, ..base }),
        ];
        let mut reps = Vec::new();
        for (name, opts) in &arms {
            let rep = run_serve(&cfg, &dir, SystemKind::Heta, opts, Backend::Channel)
                .unwrap_or_else(|e| panic!("serve arm {name}: {e:#}"));
            assert_eq!(rep.served, opts.requests, "{name}: every request must be served");
            reps.push((*name, rep));
        }
        // The invariant the cache is allowed to exist under: no arm
        // changes a single served byte.
        for (name, rep) in &reps[1..] {
            assert_eq!(
                rep.embeds, reps[0].1.embeds,
                "{name} must serve byte-identical embeddings to reuse_dedup"
            );
        }
        let full = &reps[0].1;
        let noreuse = &reps[1].1;
        assert!(
            full.ledger.fetched_rows < noreuse.ledger.fetched_rows,
            "embedding reuse must strictly reduce fetched rows ({} vs {})",
            full.ledger.fetched_rows,
            noreuse.ledger.fetched_rows
        );
        assert!(
            full.ledger.rows_per_request() < noreuse.ledger.rows_per_request(),
            "reuse must fetch fewer rows per request"
        );
        let nodedup = &reps[2].1;
        assert!(
            noreuse.ledger.fetched_rows <= nodedup.ledger.fetched_rows,
            "frontier dedup must not increase fetched rows"
        );
        let mut rows = Vec::new();
        let mut entries = Vec::new();
        for (name, rep) in &reps {
            rows.push(vec![
                name.to_string(),
                format!("{:.2}", rep.p50_ms()),
                format!("{:.2}", rep.p99_ms()),
                format!("{:.0}", rep.qps),
                format!("{}", rep.deadline_misses),
                format!("{:.1}", rep.ledger.rows_per_request()),
                format!("{:.2}", rep.ledger.hit_rate()),
            ]);
            entries.push((
                name.to_string(),
                Json::from_pairs(vec![
                    ("p50_ms", Json::num(rep.p50_ms())),
                    ("p99_ms", Json::num(rep.p99_ms())),
                    ("qps", Json::num(rep.qps)),
                    ("deadline_misses", Json::num(rep.deadline_misses as f64)),
                    ("served", Json::num(rep.served as f64)),
                    ("batches", Json::num(rep.batches as f64)),
                    ("fetched_rows", Json::num(rep.ledger.fetched_rows as f64)),
                    ("fetched_bytes", Json::num(rep.ledger.fetched_bytes as f64)),
                    ("rows_per_request", Json::num(rep.ledger.rows_per_request())),
                    ("embed_hits", Json::num(rep.ledger.embed_hits as f64)),
                    ("embed_misses", Json::num(rep.ledger.embed_misses as f64)),
                    ("computed_targets", Json::num(rep.ledger.computed_targets as f64)),
                ]),
            ));
        }
        table(
            "Serving A/B on mag-tiny (256 Zipf requests)",
            &["arm", "p50 ms", "p99 ms", "qps", "misses", "rows/req", "hit rate"],
            &rows,
        );
        report("serve/p50_ms", format!("{:.3}", full.p50_ms()));
        report("serve/p99_ms", format!("{:.3}", full.p99_ms()));
        report("serve/qps", format!("{:.1}", full.qps));
        report(
            "serve/rows_per_request_reduction",
            format!(
                "{:.2}x",
                noreuse.ledger.rows_per_request() / full.ledger.rows_per_request().max(1e-9)
            ),
        );
        Some(Json::Obj(entries.into_iter().collect()))
    } else {
        println!("skipping serve A/B: artifacts/{cfg_name} missing (run `make artifacts`)");
        None
    };

    let mut top = vec![("micro", micro)];
    if let Some(a) = arms {
        top.push(("arms", a));
    }
    let out = Json::from_pairs(vec![("serve", Json::from_pairs(top))]).to_string();
    std::fs::write("BENCH_serve.json", &out).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
