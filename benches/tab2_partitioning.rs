//! Table 2 — partitioning performance (time + peak memory) for Random,
//! METIS(-like), GraphLearn and Meta-partitioning on the MAG240M- and
//! IGB-HET-shaped datasets — plus the §4 communication-volume example
//! (92.3 MB vanilla vs 8.0 MB RAF vs 0.5 MB RAF+meta, MAG240M, B=1024,
//! fanout {25,20}, fp16).

use heta::datagen::{generate, GenParams, Preset};
use heta::hetgraph::MetaTree;
use heta::partition::{edgecut, meta::meta_partition, metis_like, quality};
use heta::sampling::{remote_counts, sample_tree, vertex_sizes, Frontier, PAD};
use heta::util::bench::{report, table};
use heta::util::{fmt_bytes, fmt_secs};

fn partition_rows(preset: Preset, scale: f64, label: &str) -> Vec<Vec<String>> {
    let g = generate(preset, scale, &GenParams::default());
    let mut rows = Vec::new();
    let r = edgecut::random(&g, 2, 1);
    rows.push(vec![
        label.into(),
        "Random".into(),
        fmt_secs(r.elapsed_s),
        fmt_bytes(r.peak_mem_bytes),
    ]);
    let m = metis_like::metis_like(&g, 2, 1);
    rows.push(vec![
        label.into(),
        "METIS-like".into(),
        fmt_secs(m.elapsed_s),
        fmt_bytes(m.peak_mem_bytes),
    ]);
    let t = edgecut::by_type(&g, 2, 1);
    rows.push(vec![
        label.into(),
        "GraphLearn".into(),
        fmt_secs(t.elapsed_s),
        fmt_bytes(t.peak_mem_bytes),
    ]);
    let (mp, _) = meta_partition(&g, 2, 2, None);
    rows.push(vec![
        label.into(),
        "Meta-partitioning".into(),
        fmt_secs(mp.elapsed_s),
        fmt_bytes(mp.peak_mem_bytes),
    ]);
    rows
}

/// §4 worked example: per-batch communication volume under the three
/// execution strategies, computed on an actual sampled 2-hop tree of the
/// MAG240M-shaped graph with the paper's parameters (fp16 = 2 B/elem).
fn comm_volume_example() {
    let g = generate(Preset::Mag240m, 2e-5, &GenParams::default());
    let tree = MetaTree::build(&g.schema, 2);
    let fanouts = [25usize, 20];
    let b = 1024usize.min(g.train_nodes().len());
    let batch: Vec<u32> = g.train_nodes()[..b].to_vec();
    let sample = sample_tree(&g, &tree, &fanouts, &batch, 0, 99, |_| true);
    let part = metis_like::metis_like(&g, 2, 1);
    let hidden = 64usize;
    let fp16 = 2u64;

    // Vanilla: every remote sampled node ships its feature row (+16 B of
    // topology per node, matching the paper's accounting).
    let rstats = remote_counts(&tree, &sample, &part, 0);
    let mut vanilla_bytes = 0u64;
    for (v, ids) in sample.ids.iter().enumerate() {
        let ty = tree.vertices[v].ty;
        let dim = g.schema.node_types[ty].feat_dim as u64;
        for &id in ids.iter().filter(|&&id| id != PAD) {
            if part.owner_of(ty, id) != 0 {
                vanilla_bytes += dim * fp16 + 16;
            }
        }
    }

    // RAF over an edge-cut-style split: hop-1 partial aggregations (plus
    // their gradients) of sampled layer-1 nodes cross partitions. The
    // frontier caches per-vertex valid counts, replacing the former
    // O(slots) `valid_count` rescans with one shared pass.
    let fr = Frontier::build(&tree, &sample, g.schema.node_types.len(), true);
    let sizes = vertex_sizes(&tree, &fanouts, b);
    let hop1: u64 = tree
        .edges
        .iter()
        .filter(|e| e.parent == 0)
        .map(|e| fr.valid_counts[e.child] as u64)
        .sum();
    let raf_bytes = (hop1 + b as u64) * hidden as u64 * fp16 * 2;

    // RAF + meta-partitioning: only target-node partials + grads.
    let meta_bytes = (b as u64) * hidden as u64 * fp16 * 2 * 2;

    report("sec4/sampled_nodes_total", fr.total_valid_slots());
    report("sec4/sampled_nodes_unique", fr.total_unique_rows());
    report("sec4/sampled_nodes_remote", rstats.remote);
    report("sec4/vanilla_bytes_per_batch", fmt_bytes(vanilla_bytes));
    report("sec4/raf_bytes_per_batch", fmt_bytes(raf_bytes));
    report("sec4/raf_meta_bytes_per_batch", fmt_bytes(meta_bytes));
    report(
        "sec4/vanilla_over_raf_meta",
        format!("{:.1}x", vanilla_bytes as f64 / meta_bytes as f64),
    );
    let _ = sizes;
}

fn main() {
    let mut rows = partition_rows(Preset::Mag240m, 4e-5, "MAG240M(scaled)");
    rows.extend(partition_rows(Preset::IgbHet, 1e-4, "IGB-HET(scaled)"));
    table(
        "Table 2: partitioning time + peak memory (2 partitions)",
        &["dataset", "method", "time", "peak memory"],
        &rows,
    );
    comm_volume_example();
}
