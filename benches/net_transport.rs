//! Channel vs loopback-TCP transport A/B (the PR-5 bench): run the
//! same cluster config on the in-process channel transport and on the
//! socket star (one thread **and one Session per rank**, real frames
//! through the codec), for both engines. Reports real wall-clock epoch
//! time, the real bytes the wire moved, the modeled bytes of the same
//! messages (the `Wire::wire_bytes` cost-model view — the gap is codec
//! + harness overhead made visible), and asserts the equivalence bar:
//! byte-identical per-batch losses across transports, with modeled
//! never exceeding real. Emits `BENCH_net.json` (uploaded by CI next
//! to the other bench artifacts).

//! Since PR 8 it also runs the wire-efficiency A/B: leader bytes per
//! batch across `wire_snapshots = full | diff` and `wire_exchange =
//! star | mesh`, at K=2 (`mag-tiny`) and K=4 (`mag-tiny-p4`, skipped
//! without its artifacts). Losses stay byte-identical across every
//! mode; the diff column must beat the full column on leader sent
//! bytes, and the mesh column must beat the star column on leader
//! received bytes. The numbers land in `BENCH_net.json` under
//! `wire_efficiency`.

use std::time::Instant;

use heta::config::{Config, RuntimeKind, WireExchange, WireSnapshots};
use heta::coordinator::{run_loopback_tcp, Engine, Session, SystemKind};
use heta::metrics::EpochReport;
use heta::util::bench::{report, table};
use heta::util::fmt_bytes;
use heta::util::fmt_secs;
use heta::util::json::Json;

const EPOCHS: usize = 2;

/// In-process channel run. The timer covers session + engine build AND
/// the epochs — the same span the TCP side measures, so the A/B
/// compares like with like (the TCP column legitimately pays one
/// session build per rank: that is the real cost of process-per-rank
/// deployment, and it is reported as such rather than folded into a
/// misleading "transport" overhead).
fn run_channel(cfg: &Config, system: SystemKind) -> (Vec<EpochReport>, f64) {
    let mut cfg = cfg.clone();
    cfg.train.runtime = RuntimeKind::Cluster;
    let dir = format!("artifacts/{}", cfg.name);
    let t0 = Instant::now();
    let mut sess = Session::new(&cfg, &dir)
        .unwrap_or_else(|e| panic!("session for {}: {e} (run `make artifacts`)", cfg.name));
    let mut engine = Engine::build(&mut sess, system).unwrap();
    let reps = (0..EPOCHS)
        .map(|ep| engine.run_epoch(&mut sess, ep).unwrap())
        .collect();
    (reps, t0.elapsed().as_secs_f64())
}

/// Loopback-TCP run (one session per rank, real sockets). Same
/// measurement span as [`run_channel`]: builds + epochs.
fn run_tcp(cfg: &Config, system: SystemKind) -> (Vec<EpochReport>, f64) {
    let mut cfg = cfg.clone();
    cfg.train.runtime = RuntimeKind::Cluster;
    let dir = format!("artifacts/{}", cfg.name);
    let t0 = Instant::now();
    let reps = run_loopback_tcp(&cfg, &dir, system, EPOCHS)
        .unwrap_or_else(|e| panic!("loopback tcp for {}: {e:#}", cfg.name));
    (reps, t0.elapsed().as_secs_f64())
}

fn main() {
    let cfg_name = "mag-tiny";
    if !heta::util::artifacts_ready(cfg_name) {
        return;
    }
    let cfg = Config::load(&format!("configs/{cfg_name}.json"))
        .unwrap_or_else(|e| panic!("loading config {cfg_name}: {e}"));

    let mut rows = Vec::new();
    let mut entries = Vec::new();
    for (system, label) in [(SystemKind::Heta, "raf"), (SystemKind::DglMetis, "vanilla")] {
        let (chan, chan_wall) = run_channel(&cfg, system);
        let (tcp, tcp_wall) = run_tcp(&cfg, system);

        // The equivalence bar, asserted where the numbers are made.
        for (ep, (c, t)) in chan.iter().zip(&tcp).enumerate() {
            assert_eq!(
                c.batch_losses.len(),
                t.batch_losses.len(),
                "{label} epoch {ep}: batch counts diverged across transports"
            );
            for (bi, (lc, lt)) in c.batch_losses.iter().zip(&t.batch_losses).enumerate() {
                assert_eq!(
                    lc.to_bits(),
                    lt.to_bits(),
                    "{label} epoch {ep} batch {bi}: losses diverged across transports"
                );
            }
        }
        let wire = tcp.iter().fold(heta::net::WireTraffic::default(), |mut acc, r| {
            acc.merge(&r.wire);
            acc
        });
        assert!(wire.real_total() > 0, "{label}: the tcp run must move real bytes");
        assert!(
            wire.modeled_total() <= wire.real_total(),
            "{label}: modeled bytes exceed the wire's real bytes"
        );

        for (transport, wall, w) in [
            ("channel", chan_wall, None),
            ("tcp", tcp_wall, Some(&wire)),
        ] {
            rows.push(vec![
                label.to_string(),
                transport.to_string(),
                // Wall includes session/engine builds (per rank on tcp).
                fmt_secs(wall / EPOCHS as f64),
                w.map_or("0 B".into(), |w| fmt_bytes(w.real_total())),
                w.map_or("0 B".into(), |w| fmt_bytes(w.modeled_total())),
                w.map_or("0".into(), |w| w.frames().to_string()),
            ]);
            entries.push(Json::from_pairs(vec![
                ("engine", Json::str(label)),
                ("config", Json::str(cfg_name)),
                ("transport", Json::str(transport)),
                ("epochs", Json::num(EPOCHS as f64)),
                ("wall_per_epoch_s", Json::num(wall / EPOCHS as f64)),
                (
                    "real_bytes",
                    Json::num(w.map_or(0, |w| w.real_total()) as f64),
                ),
                (
                    "modeled_bytes",
                    Json::num(w.map_or(0, |w| w.modeled_total()) as f64),
                ),
                ("frames", Json::num(w.map_or(0, |w| w.frames()) as f64)),
            ]));
        }
        report(
            &format!("net/{label}/tcp_wall_overhead"),
            format!("{:.2}x", tcp_wall / chan_wall.max(1e-9)),
        );
        report(
            &format!("net/{label}/codec_overhead"),
            format!(
                "{:.2}x real/modeled",
                wire.real_total() as f64 / (wire.modeled_total().max(1)) as f64
            ),
        );
    }
    table(
        "Wire transport: channel vs loopback TCP (losses byte-identical; \
         wall spans build+epochs — tcp builds one session per rank)",
        &["engine", "transport", "wall/epoch", "real bytes", "modeled bytes", "frames"],
        &rows,
    );

    // ---- PR 8: leader bytes per batch across the wire knobs ----
    let mut wire_rows = Vec::new();
    let mut wire_entries = Vec::new();
    for wire_cfg in ["mag-tiny", "mag-tiny-p4"] {
        if !heta::util::artifacts_ready(wire_cfg) {
            println!("wire-efficiency: skipping {wire_cfg} (run `make artifacts`)");
            continue;
        }
        let base = Config::load(&format!("configs/{wire_cfg}.json"))
            .unwrap_or_else(|e| panic!("loading config {wire_cfg}: {e}"));
        let k = base.train.num_partitions;
        for (system, label) in [(SystemKind::Heta, "raf"), (SystemKind::DglMetis, "vanilla")] {
            // The mesh only reroutes the RAF partial aggregation;
            // vanilla has no partial exchange, so its matrix is 1-D.
            let modes: &[(WireSnapshots, WireExchange)] = if system == SystemKind::Heta {
                &[
                    (WireSnapshots::Full, WireExchange::Star),
                    (WireSnapshots::Diff, WireExchange::Star),
                    (WireSnapshots::Diff, WireExchange::Mesh),
                ]
            } else {
                &[
                    (WireSnapshots::Full, WireExchange::Star),
                    (WireSnapshots::Diff, WireExchange::Star),
                ]
            };
            let mut per_mode = Vec::new();
            for &(snaps, exch) in modes {
                let mut cfg = base.clone();
                cfg.train.wire_snapshots = snaps;
                cfg.train.wire_exchange = exch;
                let (reps, _) = run_tcp(&cfg, system);
                let batches: usize = reps.iter().map(|r| r.batches).sum();
                assert!(batches > 0, "{label}/{wire_cfg}: the A/B needs batches to price");
                let wire = reps.iter().fold(heta::net::WireTraffic::default(), |mut a, r| {
                    a.merge(&r.wire);
                    a
                });
                let losses: Vec<u64> = reps
                    .iter()
                    .flat_map(|r| r.batch_losses.iter().map(|l| l.to_bits()))
                    .collect();
                let mode = format!("{}/{}", snaps.name(), exch.name());
                wire_rows.push(vec![
                    label.to_string(),
                    format!("K={k}"),
                    mode.clone(),
                    fmt_bytes(wire.real_sent / batches as u64),
                    fmt_bytes(wire.real_recv / batches as u64),
                    fmt_bytes(wire.mesh_sent + wire.mesh_recv),
                ]);
                wire_entries.push(Json::from_pairs(vec![
                    ("engine", Json::str(label)),
                    ("config", Json::str(wire_cfg)),
                    ("workers", Json::num(k as f64)),
                    ("wire_snapshots", Json::str(snaps.name())),
                    ("wire_exchange", Json::str(exch.name())),
                    ("batches", Json::num(batches as f64)),
                    (
                        "leader_sent_bytes_per_batch",
                        Json::num((wire.real_sent / batches as u64) as f64),
                    ),
                    (
                        "leader_recv_bytes_per_batch",
                        Json::num((wire.real_recv / batches as u64) as f64),
                    ),
                ]));
                per_mode.push((mode, wire, losses));
            }
            // Equivalence across every mode, against the first.
            let (ref_mode, _, ref_losses) = &per_mode[0];
            for (mode, _, losses) in &per_mode[1..] {
                assert_eq!(
                    losses, ref_losses,
                    "{label}/{wire_cfg}: losses diverged between {ref_mode} and {mode}"
                );
            }
            // The byte wins the tentpole promises.
            let sent = |i: usize| per_mode[i].1.real_sent;
            assert!(
                sent(1) < sent(0),
                "{label}/{wire_cfg}: diff snapshots must shrink leader sent bytes \
                 ({} >= {})",
                sent(1),
                sent(0)
            );
            if per_mode.len() > 2 {
                let recv = |i: usize| per_mode[i].1.real_recv;
                assert!(
                    recv(2) < recv(1),
                    "{label}/{wire_cfg}: the mesh must shrink leader received bytes \
                     ({} >= {})",
                    recv(2),
                    recv(1)
                );
            }
            report(
                &format!("net/{label}/k{k}/diff_sent_ratio"),
                format!("{:.2}x", sent(1) as f64 / sent(0).max(1) as f64),
            );
        }
    }
    if !wire_rows.is_empty() {
        table(
            "Wire efficiency: leader bytes per batch across wire knobs \
             (losses byte-identical in every mode; leader counters only — \
             mesh relay bytes live on the workers)",
            &["engine", "cluster", "mode", "sent/batch", "recv/batch", "leader mesh bytes"],
            &wire_rows,
        );
    }

    let out = Json::from_pairs(vec![
        ("net_transport", Json::Arr(entries)),
        ("wire_efficiency", Json::Arr(wire_entries)),
    ])
    .to_string();
    std::fs::write("BENCH_net.json", &out).expect("write BENCH_net.json");
    println!("wrote BENCH_net.json");
}
