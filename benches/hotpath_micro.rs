//! Microbenchmarks of the L3 hot paths — sampling, feature gather
//! (padded-block fill), sparse Adam, cache lookup, partitioning — the
//! targets of the §Perf optimization pass (EXPERIMENTS.md §Perf records
//! before/after for each).

use heta::cache::{FeatureCache, Policy, TypeProfile};
use heta::comm::CostModel;
use heta::datagen::{generate, GenParams, Preset};
use heta::hetgraph::MetaTree;
use heta::kvstore::FeatureStore;
use heta::optim::{accumulate_rows, sparse_adam_step, AdamParams};
use heta::sampling::{presample_hotness, sample_tree, PAD};
use heta::util::bench::{black_box, Bench};

fn main() {
    let b = Bench::new("hotpath").with_budget(1.0);
    let g = generate(Preset::Mag, 1e-3, &GenParams::default());
    let tree = MetaTree::build(&g.schema, 2);
    let batch: Vec<u32> = g.train_nodes()[..64].to_vec();
    let fanouts = [10usize, 5];

    b.run("sample_tree/b64_f10x5", || {
        black_box(sample_tree(&g, &tree, &fanouts, &batch, 0, 7, |_| true));
    });

    let store = FeatureStore::new(&g, 1);
    let sample = sample_tree(&g, &tree, &fanouts, &batch, 0, 7, |_| true);
    let ids = &sample.ids[1];
    let dim = store.dim(tree.vertices[1].ty);
    let mut buf = vec![0f32; ids.len() * dim];
    b.run("gather/640rows", || {
        black_box(store.gather(tree.vertices[1].ty, ids, &mut buf, |_| false));
    });

    // Sparse Adam over ~640 rows of a 64-dim table.
    let n = g.schema.node_types[1].count;
    let mut w = vec![0.1f32; n * 64];
    let mut m = vec![0f32; n * 64];
    let mut v = vec![0f32; n * 64];
    let grads = vec![0.01f32; ids.len() * 64];
    b.run("sparse_adam/640rows", || {
        let rows = accumulate_rows(ids, &grads, 64, PAD);
        black_box(sparse_adam_step(&rows, &mut w, &mut m, &mut v, 64, 1, AdamParams::default()));
    });

    // Cache access path.
    let hotness = presample_hotness(&g, &tree, &fanouts, 64, 1, 3);
    let profiles: Vec<TypeProfile> = g
        .schema
        .node_types
        .iter()
        .map(|t| TypeProfile {
            name: t.name.clone(),
            count: t.count,
            feat_dim: t.feat_dim,
            learnable: t.learnable,
        })
        .collect();
    let cost = CostModel::default();
    let mut cache = FeatureCache::build(
        Policy::HotnessMissPenalty,
        &profiles,
        &hotness,
        &cost,
        4 << 20,
        2,
    );
    b.run("cache_access/640", || {
        let mut t = 0.0;
        for &id in ids.iter().filter(|&&i| i != PAD) {
            t += cache.access(&cost, 1, id, 0, false);
        }
        black_box(t);
    });

    b.run("meta_partition/mag-1e3", || {
        black_box(heta::partition::meta::meta_partition(&g, 2, 2, None));
    });
}
