//! Figure 7 — miss-penalty ratios per node type (Donor, ogbn-mag);
//! Figure 11 — epoch time under the three cache policies (no cache /
//! hotness-only / hotness+miss-penalty);
//! Figure 12 — per-type cache hit rates, Heta vs DGL-Opt vs GraphLearn
//! (R-GAT on IGB-HET).

use heta::cache::{miss_penalty_ratio, Policy};
use heta::config::Config;
use heta::coordinator::{Engine, Session, SystemKind};
use heta::datagen::{schema, Preset};
use heta::util::bench::table;
use heta::util::fmt_secs;

fn fig7() {
    let cost = heta::comm::CostModel::default();
    let mut rows = Vec::new();
    for (preset, label) in [(Preset::Donor, "Donor"), (Preset::Mag, "ogbn-mag")] {
        let s = schema(preset, 1e-4);
        for t in &s.node_types {
            let o = miss_penalty_ratio(&cost, t.feat_dim, t.learnable);
            rows.push(vec![
                label.into(),
                t.name.clone(),
                t.feat_dim.to_string(),
                if t.learnable { "learnable" } else { "read-only" }.into(),
                format!("{:.2}", o * 1e9),
            ]);
        }
    }
    table(
        "Fig 7: miss-penalty ratio per node type (ns per feature byte)",
        &["dataset", "type", "dim", "kind", "o_a (ns/B)"],
        &rows,
    );
}

fn fig11() {
    let mut rows = Vec::new();
    for cfg_name in ["donor-bench", "mag240m-bench", "igb-bench", "mag-bench"] {
        let mut no_cache = f64::NAN;
        for (policy, label) in [
            (Policy::None, "no-cache"),
            (Policy::HotnessOnly, "hotness-only"),
            (Policy::HotnessMissPenalty, "hotness+miss-penalty"),
        ] {
            let mut cfg = Config::load(&format!("configs/{cfg_name}.json")).unwrap();
            cfg.train.cache_policy = policy;
            let mut sess = Session::new(&cfg, &format!("artifacts/{cfg_name}")).unwrap();
            let mut eng = Engine::build(&mut sess, SystemKind::Heta).unwrap();
            let rep = eng.run_epoch(&mut sess, 0).unwrap();
            if policy == Policy::None {
                no_cache = rep.epoch_time_s;
            }
            rows.push(vec![
                cfg_name.into(),
                label.into(),
                fmt_secs(rep.epoch_time_s),
                format!("{:.2}x", no_cache / rep.epoch_time_s),
            ]);
        }
    }
    table(
        "Fig 11: cache-policy ablation (speedup vs no-cache)",
        &["dataset", "policy", "epoch time", "speedup"],
        &rows,
    );
}

fn fig12() {
    let cfg_name = "igb-bench-rgat";
    let mut rows = Vec::new();
    for sys in [SystemKind::Heta, SystemKind::DglOpt, SystemKind::GraphLearn] {
        let cfg = Config::load(&format!("configs/{cfg_name}.json")).unwrap();
        let g = cfg.build_graph();
        let mut sess = Session::new(&cfg, &format!("artifacts/{cfg_name}")).unwrap();
        let mut eng = Engine::build(&mut sess, sys).unwrap();
        let _ = eng.run_epoch(&mut sess, 0).unwrap();
        let rates: Vec<Vec<f64>> = match &eng {
            Engine::Raf(r) => r.hit_rates(),
            Engine::Vanilla(v) => v.hit_rates(),
        };
        // Average across machines per type.
        if rates.is_empty() {
            continue;
        }
        let types = rates[0].len();
        for ty in 0..types {
            let avg: f64 =
                rates.iter().map(|m| m[ty]).sum::<f64>() / rates.len() as f64;
            rows.push(vec![
                sys.name().into(),
                g.schema.node_types[ty].name.clone(),
                format!("{:.1}%", avg * 100.0),
            ]);
        }
    }
    table(
        "Fig 12: cache hit rate per node type (R-GAT, IGB-HET)",
        &["system", "node type", "hit rate"],
        &rows,
    );
}

fn main() {
    fig7();
    fig11();
    fig12();
}
