//! Sequential vs cluster-pipelined RAF epoch time on the `fig8_9`
//! overall config. Reports the classic summed epoch time next to the
//! overlap-aware critical path of the double-buffered cluster runtime,
//! and emits a `BENCH_pipeline.json` entry with the overlap saving.
//! The acceptance shape: the pipelined critical path is strictly lower
//! than the sequential runtime's epoch time (sampling of batch i+1
//! hides inside batch i's leader phase).

use heta::config::{Config, RuntimeKind};
use heta::coordinator::{Engine, Session, SystemKind};
use heta::metrics::EpochReport;
use heta::util::bench::{report, table};
use heta::util::fmt_secs;
use heta::util::json::Json;

fn run(cfg: &Config, runtime: RuntimeKind, pipeline: bool) -> EpochReport {
    let mut cfg = cfg.clone();
    cfg.train.runtime = runtime;
    cfg.train.pipeline = pipeline;
    let dir = format!("artifacts/{}", cfg.name);
    let mut sess = Session::new(&cfg, &dir)
        .unwrap_or_else(|e| panic!("session for {}: {e} (run `make artifacts`)", cfg.name));
    let mut engine = Engine::build(&mut sess, SystemKind::Heta).unwrap();
    engine.run_epoch(&mut sess, 0).unwrap()
}

fn main() {
    let cfg_name = "mag-bench";
    if !heta::util::artifacts_ready(cfg_name) {
        return;
    }
    let cfg = Config::load(&format!("configs/{cfg_name}.json"))
        .unwrap_or_else(|e| panic!("loading config {cfg_name}: {e}"));

    let seq = run(&cfg, RuntimeKind::Sequential, false);
    let clu_nopipe = run(&cfg, RuntimeKind::Cluster, false);
    let clu_pipe = run(&cfg, RuntimeKind::Cluster, true);

    let rows = vec![
        vec![
            "sequential".to_string(),
            fmt_secs(seq.epoch_time_s),
            fmt_secs(seq.critical_path_s),
            "1.00x".to_string(),
        ],
        vec![
            "cluster/no-pipeline".to_string(),
            fmt_secs(clu_nopipe.epoch_time_s),
            fmt_secs(clu_nopipe.critical_path_s),
            format!("{:.2}x", seq.critical_path_s / clu_nopipe.critical_path_s),
        ],
        vec![
            "cluster/pipelined".to_string(),
            fmt_secs(clu_pipe.epoch_time_s),
            fmt_secs(clu_pipe.critical_path_s),
            format!("{:.2}x", seq.critical_path_s / clu_pipe.critical_path_s),
        ],
    ];
    table(
        "Pipeline overlap: RAF epoch time, fig8_9 overall config",
        &["runtime", "summed", "critical path", "speedup"],
        &rows,
    );

    let saving = clu_pipe.epoch_time_s - clu_pipe.critical_path_s;
    report("pipeline/overlap_saving_s", format!("{saving:.6}"));
    report(
        "pipeline/critical_path_below_sequential",
        clu_pipe.critical_path_s < seq.critical_path_s,
    );
    assert_eq!(
        seq.loss_mean, clu_pipe.loss_mean,
        "runtimes must train identically"
    );

    let entry = Json::from_pairs(vec![
        ("config", Json::str(cfg_name)),
        ("engine", Json::str("raf")),
        ("sequential_epoch_s", Json::num(seq.epoch_time_s)),
        ("cluster_summed_s", Json::num(clu_pipe.epoch_time_s)),
        ("cluster_critical_path_s", Json::num(clu_pipe.critical_path_s)),
        (
            "cluster_nopipeline_critical_path_s",
            Json::num(clu_nopipe.critical_path_s),
        ),
        ("overlap_saving_s", Json::num(saving)),
        (
            "speedup_vs_sequential",
            Json::num(seq.critical_path_s / clu_pipe.critical_path_s),
        ),
        (
            "worker_busy_s",
            Json::Arr(clu_pipe.worker_busy_s.iter().map(|&b| Json::num(b)).collect()),
        ),
    ]);
    let out = Json::from_pairs(vec![("pipeline_overlap", entry)]).to_string();
    std::fs::write("BENCH_pipeline.json", &out).expect("write BENCH_pipeline.json");
    println!("wrote BENCH_pipeline.json");
}
