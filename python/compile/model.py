"""Layer-2 HGNN compute graphs (R-GCN / R-GAT / HGT), composed along the
metatree of a Rust-emitted artifact plan (``artifacts/<cfg>/plan.json``).

The model family follows paper Eq. (1): per metatree vertex, a
relation-specific aggregation per child edge (Layer-1 Pallas kernels),
summed across relations (``AGG_all``) with a per-type self term and ReLU.
Two layers (paper default):

  h1[t]  = relu(x_t @ Wself1_ty + sum_f AGG_f^1(x_children(f)))   (depth-1)
  p1     = sum_e AGG_e^1(x_child(e))                              (root L1 partials)
  p2     = sum_e AGG_e^2(h1[child(e)])                            (root L2 partials)
  leader: h1r = relu(x_root @ Wself1 + p1); h2r = relu(h1r @ Wself2 + p2)
          loss = CE(h2r @ Whead, labels)

RAF splits the `sum_e` across partitions (each worker emits its p1/p2
contribution); the leader owns the self/head weights. For R-GAT/HGT, the
attention query at root level uses the (replicated) raw target features —
a model-definition choice that keeps RAF single-phase; both engines
compute the same definition, preserving Prop. 1 equivalence (DESIGN.md).

Every exported artifact is a pure function over a *flat tuple* of f32/i32
arrays whose order is recorded in a manifest (``manifest.json``) — the
only contract the Rust runtime needs.
"""

import json
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels.gat_agg import gat_agg_op
from .kernels.hgt_agg import hgt_agg_op
from .kernels.relation_agg import relation_agg_op


# --------------------------------------------------------------------------
# Plan loading
# --------------------------------------------------------------------------

@dataclass
class Plan:
    raw: dict

    @staticmethod
    def load(path: str) -> "Plan":
        with open(path) as f:
            return Plan(json.load(f))

    @property
    def arch(self):
        return self.raw["arch"]

    @property
    def hidden(self):
        return self.raw["hidden"]

    @property
    def heads(self):
        return self.raw["heads"]

    @property
    def num_classes(self):
        return self.raw["num_classes"]

    @property
    def batch(self):
        return self.raw["batch"]

    @property
    def vanilla_batch(self):
        return self.raw["vanilla_batch"]

    @property
    def fanouts(self):
        return self.raw["fanouts"]

    @property
    def edges(self):
        return self.raw["edges"]

    @property
    def vertices(self):
        return self.raw["vertices"]

    @property
    def target(self):
        return self.raw["target"]

    @property
    def partitions(self):
        return [p["edges"] for p in self.raw["partitions"]]

    def vertex_sizes(self, batch: int) -> dict:
        """Padded slot count per vertex for a given root batch."""
        sizes = {0: batch}
        for e in self.edges:  # BFS order: parents precede children
            sizes[e["child"]] = sizes[e["parent"]] * e["k"]
        return sizes


# --------------------------------------------------------------------------
# Manifest specs
# --------------------------------------------------------------------------

@dataclass
class InputSpec:
    kind: str                  # block|mask|weight|target_feat|labels|grad|partial_sum
    shape: tuple
    name: str = ""             # weight name
    edge: int = -1             # block/mask edge id
    layer: int = 0             # grad/partial layer
    dtype: str = "f32"
    init: str = ""             # glorot|zeros (weights only)

    def to_json(self):
        d = {"kind": self.kind, "shape": list(self.shape), "dtype": self.dtype}
        if self.name:
            d["name"] = self.name
        if self.edge >= 0:
            d["edge"] = self.edge
        if self.layer:
            d["layer"] = self.layer
        if self.init:
            d["init"] = self.init
        return d


@dataclass
class OutputSpec:
    kind: str                  # partial|loss|acc|gpartial|wgrad|block_grad|target_feat_grad|logits
    name: str = ""
    edge: int = -1
    layer: int = 0

    def to_json(self):
        d = {"kind": self.kind}
        if self.name:
            d["name"] = self.name
        if self.edge >= 0:
            d["edge"] = self.edge
        if self.layer:
            d["layer"] = self.layer
        return d


@dataclass
class Artifact:
    name: str
    fn: Callable               # flat-args -> tuple of outputs
    inputs: list = field(default_factory=list)
    outputs: list = field(default_factory=list)

    def example_args(self):
        specs = []
        for s in self.inputs:
            dt = jnp.int32 if s.dtype == "i32" else jnp.float32
            specs.append(jax.ShapeDtypeStruct(tuple(s.shape), dt))
        return specs


# --------------------------------------------------------------------------
# Weight catalogues per architecture
# --------------------------------------------------------------------------

def _glorot(shape):
    return InputSpec("weight", shape, init="glorot")


def rel_weight_specs(arch, edge, layer, hidden, heads, f_dst):
    """Weights of one relation aggregation at a given layer. ``f_dst`` is
    the destination-side feature dim (attention query input)."""
    f_in = edge["f_src"] if layer == 1 else hidden
    r = edge["rel_name"]
    h = hidden
    if arch == "rgcn":
        names = [(f"W{layer}_{r}", (f_in, h))]
    elif arch == "rgat":
        names = [
            (f"W{layer}_{r}", (f_in, h)),
            (f"Wq{layer}_{r}", (f_dst, h)),
            (f"al{layer}_{r}", (h,)),
            (f"ar{layer}_{r}", (h,)),
        ]
    elif arch == "hgt":
        names = [
            (f"K{layer}_{r}", (f_in, h)),
            (f"V{layer}_{r}", (f_in, h)),
            (f"Q{layer}_{r}", (f_dst, h)),
            (f"M{layer}_{r}", (h, h)),
        ]
    else:
        raise ValueError(arch)
    out = []
    for n, shape in names:
        s = _glorot(shape)
        s.name = n
        out.append(s)
    return out


def agg_apply(arch, heads, weights, x, mask, dst_x):
    """Dispatch one relation aggregation to the Layer-1 kernel."""
    if arch == "rgcn":
        (w,) = weights
        return relation_agg_op(x, mask, w)
    if arch == "rgat":
        w, wq, al, ar = weights
        return gat_agg_op(x, mask, dst_x, w, wq, al, ar)
    if arch == "hgt":
        wk, wv, wq, m = weights
        return hgt_agg_op(x, mask, dst_x, wk, wv, wq, m, heads=heads)
    raise ValueError(arch)


# --------------------------------------------------------------------------
# Tree forward (shared by worker / vanilla artifacts)
# --------------------------------------------------------------------------

def build_tree_inputs(plan: Plan, edge_ids, batch):
    """Input specs for the blocks+masks of a set of tree edges, plus the
    weights they need, plus (attention archs) the replicated target
    features. Returns (input_specs, index maps)."""
    arch, hidden, heads = plan.arch, plan.hidden, plan.heads
    sizes = plan.vertex_sizes(batch)
    edges = {e["id"]: e for e in plan.edges}
    vtx = {v["id"]: v for v in plan.vertices}
    needs_dst = arch in ("rgat", "hgt")

    specs, block_ix, mask_ix = [], {}, {}
    for ei in sorted(edge_ids):
        e = edges[ei]
        s = sizes[e["parent"]]
        block_ix[ei] = len(specs)
        specs.append(InputSpec("block", (s, e["k"], e["f_src"]), edge=ei))
        mask_ix[ei] = len(specs)
        specs.append(InputSpec("mask", (s, e["k"]), edge=ei))

    # Weight list: dedup by name, in deterministic (edge, layer) order.
    weight_ix = {}
    wspecs = []

    def add_weights(ws):
        ix = []
        for s in ws:
            if s.name not in weight_ix:
                weight_ix[s.name] = len(wspecs)
                wspecs.append(s)
            ix.append(weight_ix[s.name])
        return ix

    # Per-edge aggregation weights. Root edges (depth 0) are used at both
    # layers; deeper edges only at layer 1.
    agg_w = {}
    for ei in sorted(edge_ids):
        e = edges[ei]
        if e["depth"] == 0:
            f_dst = plan.target["feat_dim"]
            agg_w[(ei, 1)] = add_weights(
                rel_weight_specs(arch, e, 1, hidden, heads, f_dst)
            )
            agg_w[(ei, 2)] = add_weights(
                rel_weight_specs(arch, e, 2, hidden, heads, f_dst)
            )
        else:
            f_dst = vtx[e["parent"]]["feat_dim"]
            agg_w[(ei, 1)] = add_weights(
                rel_weight_specs(arch, e, 1, hidden, heads, f_dst)
            )

    # Self weights for depth-1 vertices present in this edge set.
    self_w = {}
    for ei in sorted(edge_ids):
        e = edges[ei]
        if e["depth"] == 0:
            tyname = vtx[e["child"]]["type_name"]
            if tyname not in self_w:
                s = _glorot((vtx[e["child"]]["feat_dim"], hidden))
                s.name = f"Wself1_{tyname}"
                self_w[tyname] = add_weights([s])[0]

    # Target features (attention query at root level).
    tf_ix = None
    if needs_dst:
        tf_ix = len(specs) + len(wspecs)
        # placeholder — appended after weights below

    all_specs = specs + wspecs
    if needs_dst:
        all_specs.append(
            InputSpec("target_feat", (batch, plan.target["feat_dim"]))
        )

    ix = {
        "block": block_ix,
        "mask": mask_ix,
        "weight_base": len(specs),
        "agg_w": agg_w,
        "self_w": self_w,
        "target_feat": tf_ix,
        "num_weights": len(wspecs),
    }
    return all_specs, ix


def tree_forward(plan: Plan, edge_ids, batch, ix, args):
    """Compute (p1, p2) root partials for a set of tree edges given flat
    ``args`` ordered per :func:`build_tree_inputs`."""
    arch, hidden, heads = plan.arch, plan.hidden, plan.heads
    edges = {e["id"]: e for e in plan.edges}
    vtx = {v["id"]: v for v in plan.vertices}
    wb = ix["weight_base"]

    def W(widx_list):
        return [args[wb + i] for i in widx_list]

    def blk(ei):
        return args[ix["block"][ei]], args[ix["mask"][ei]]

    x_root = args[ix["target_feat"]] if ix["target_feat"] is not None else None

    root_edges = [edges[ei] for ei in sorted(edge_ids) if edges[ei]["depth"] == 0]
    by_parent = {}
    for ei in sorted(edge_ids):
        e = edges[ei]
        if e["depth"] >= 1:
            by_parent.setdefault(e["parent"], []).append(e)

    # Depth-1 vertex embeddings h1[t].
    h1 = {}
    for e in root_edges:
        t = e["child"]
        x_e, m_e = blk(e["id"])
        s_t = x_e.shape[0] * x_e.shape[1]
        x_t = x_e.reshape(s_t, e["f_src"])
        m_t = m_e.reshape(s_t)
        agg = jnp.zeros((s_t, hidden), jnp.float32)
        for f in by_parent.get(t, []):
            x_f, m_f = blk(f["id"])
            agg = agg + agg_apply(
                arch, heads, W(ix["agg_w"][(f["id"], 1)]), x_f, m_f, x_t
            )
        wself = args[wb + ix["self_w"][vtx[t]["type_name"]]]
        h1[t] = jax.nn.relu(x_t @ wself + agg) * m_t[:, None]

    # Root partials.
    p1 = jnp.zeros((batch, hidden), jnp.float32)
    p2 = jnp.zeros((batch, hidden), jnp.float32)
    for e in root_edges:
        x_e, m_e = blk(e["id"])
        p1 = p1 + agg_apply(
            arch, heads, W(ix["agg_w"][(e["id"], 1)]), x_e, m_e, x_root
        )
        h1_t = h1[e["child"]].reshape(batch, e["k"], hidden)
        p2 = p2 + agg_apply(
            arch, heads, W(ix["agg_w"][(e["id"], 2)]), h1_t, m_e, x_root
        )
    return p1, p2


# --------------------------------------------------------------------------
# Leader / head computation
# --------------------------------------------------------------------------

def leader_specs(plan: Plan):
    b, h, f = plan.batch, plan.hidden, plan.target["feat_dim"]
    c = plan.num_classes
    specs = [
        InputSpec("partial_sum", (b, h), layer=1),
        InputSpec("partial_sum", (b, h), layer=2),
        InputSpec("target_feat", (b, f)),
        InputSpec("labels", (b,), dtype="i32"),
    ]
    for nm, shape in [("Wself1_target", (f, h)), ("Wself2_target", (h, h)), ("Whead", (h, c))]:
        s = _glorot(shape)
        s.name = nm
        specs.append(s)
    return specs


def head_forward(p1, p2, x_root, labels, wself1, wself2, whead, num_classes):
    h1 = jax.nn.relu(x_root @ wself1 + p1)
    h2 = jax.nn.relu(h1 @ wself2 + p2)
    logits = h2 @ whead
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    loss = -(onehot * logp).sum(-1).mean()
    acc = (jnp.argmax(logits, -1) == labels).sum().astype(jnp.float32)
    return loss, acc


# --------------------------------------------------------------------------
# Artifact builders
# --------------------------------------------------------------------------

def build_worker_fwd(plan: Plan, part: int) -> Artifact:
    edge_ids = plan.partitions[part]
    specs, ix = build_tree_inputs(plan, edge_ids, plan.batch)

    def fn(*args):
        p1, p2 = tree_forward(plan, edge_ids, plan.batch, ix, args)
        return p1, p2

    return Artifact(
        name=f"worker_fwd_p{part}",
        fn=fn,
        inputs=specs,
        outputs=[OutputSpec("partial", layer=1), OutputSpec("partial", layer=2)],
    )


def build_worker_bwd(plan: Plan, part: int) -> Artifact:
    """Backward: same inputs + (g1, g2); recomputes the forward
    (rematerialization — the L2 memory/compute choice, DESIGN §Perf) and
    returns weight grads, learnable block grads, and the target-feature
    grad when attention uses it."""
    edge_ids = plan.partitions[part]
    specs, ix = build_tree_inputs(plan, edge_ids, plan.batch)
    b, h = plan.batch, plan.hidden
    n_in = len(specs)
    wb, nw = ix["weight_base"], ix["num_weights"]
    edges = {e["id"]: e for e in plan.edges}
    learnable_edges = [
        ei for ei in sorted(edge_ids) if edges[ei]["src_learnable"]
    ]
    has_tf = ix["target_feat"] is not None

    specs_bwd = specs + [
        InputSpec("grad", (b, h), layer=1),
        InputSpec("grad", (b, h), layer=2),
    ]

    def fn(*args):
        inputs, g1, g2 = args[:n_in], args[n_in], args[n_in + 1]

        def loss_like(weights, blocks, tf):
            a = list(inputs)
            a[wb : wb + nw] = weights
            for ei, blk in zip(learnable_edges, blocks):
                a[ix["block"][ei]] = blk
            if has_tf:
                a[ix["target_feat"]] = tf
            p1, p2 = tree_forward(plan, edge_ids, b, ix, a)
            return (p1 * g1).sum() + (p2 * g2).sum()

        weights = tuple(inputs[wb : wb + nw])
        blocks = tuple(inputs[ix["block"][ei]] for ei in learnable_edges)
        tf = inputs[ix["target_feat"]] if has_tf else jnp.zeros((1, 1))
        gw, gb, gtf = jax.grad(loss_like, argnums=(0, 1, 2))(weights, blocks, tf)
        outs = list(gw) + list(gb)
        if has_tf:
            outs.append(gtf)
        return tuple(outs)

    outputs = [OutputSpec("wgrad", name=specs[wb + i].name) for i in range(nw)]
    outputs += [OutputSpec("block_grad", edge=ei) for ei in learnable_edges]
    if has_tf:
        outputs.append(OutputSpec("target_feat_grad"))
    return Artifact(
        name=f"worker_bwd_p{part}", fn=fn, inputs=specs_bwd, outputs=outputs
    )


def build_leader(plan: Plan) -> Artifact:
    specs = leader_specs(plan)
    c = plan.num_classes

    def fn(*args):
        p1, p2, x_root, labels, w1, w2, wh = args

        def loss_fn(p1, p2, x_root, w1, w2, wh):
            loss, acc = head_forward(p1, p2, x_root, labels, w1, w2, wh, c)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3, 4, 5), has_aux=True)(
            p1, p2, x_root, w1, w2, wh
        )
        g1, g2, gx, gw1, gw2, gwh = grads
        return loss, acc, g1, g2, gx, gw1, gw2, gwh

    return Artifact(
        name="leader",
        fn=fn,
        inputs=specs,
        outputs=[
            OutputSpec("loss"),
            OutputSpec("acc"),
            OutputSpec("gpartial", layer=1),
            OutputSpec("gpartial", layer=2),
            OutputSpec("target_feat_grad"),
            OutputSpec("wgrad", name="Wself1_target"),
            OutputSpec("wgrad", name="Wself2_target"),
            OutputSpec("wgrad", name="Whead"),
        ],
    )


def build_vanilla(plan: Plan) -> Artifact:
    """Full-model fwd+bwd in one module (the vanilla engine's per-worker
    data-parallel step over its microbatch)."""
    all_edges = sorted(e["id"] for e in plan.edges)
    vb = plan.vanilla_batch
    specs, ix = build_tree_inputs(plan, all_edges, vb)
    arch = plan.arch
    needs_dst = arch in ("rgat", "hgt")
    f, h, c = plan.target["feat_dim"], plan.hidden, plan.num_classes
    edges = {e["id"]: e for e in plan.edges}
    learnable_edges = [ei for ei in all_edges if edges[ei]["src_learnable"]]

    # Vanilla also owns the head weights + target feats + labels.
    if not needs_dst:
        specs = specs + [InputSpec("target_feat", (vb, f))]
        tf_pos = len(specs) - 1
    else:
        tf_pos = ix["target_feat"]
    head_names = [("Wself1_target", (f, h)), ("Wself2_target", (h, h)), ("Whead", (h, c))]
    head_pos = len(specs)
    for nm, shape in head_names:
        s = _glorot(shape)
        s.name = nm
        specs.append(s)
    specs.append(InputSpec("labels", (vb,), dtype="i32"))
    lab_pos = len(specs) - 1

    wb, nw = ix["weight_base"], ix["num_weights"]
    n_in = len(specs)

    def fn(*args):
        inputs = args[:n_in]
        labels = inputs[lab_pos]

        def loss_fn(weights, blocks, tf, heads_w):
            a = list(inputs)
            a[wb : wb + nw] = weights
            for ei, blk in zip(learnable_edges, blocks):
                a[ix["block"][ei]] = blk
            a[tf_pos] = tf
            p1, p2 = tree_forward(plan, all_edges, vb, ix, a)
            w1, w2, wh = heads_w
            loss, acc = head_forward(p1, p2, tf, labels, w1, w2, wh, c)
            return loss, acc

        weights = tuple(inputs[wb : wb + nw])
        blocks = tuple(inputs[ix["block"][ei]] for ei in learnable_edges)
        tf = inputs[tf_pos]
        heads_w = tuple(inputs[head_pos : head_pos + 3])
        (loss, acc), grads = jax.value_and_grad(
            loss_fn, argnums=(0, 1, 2, 3), has_aux=True
        )(weights, blocks, tf, heads_w)
        gw, gb, gtf, gh = grads
        return (loss, acc) + tuple(gw) + tuple(gb) + (gtf,) + tuple(gh)

    outputs = [OutputSpec("loss"), OutputSpec("acc")]
    outputs += [OutputSpec("wgrad", name=specs[wb + i].name) for i in range(nw)]
    outputs += [OutputSpec("block_grad", edge=ei) for ei in learnable_edges]
    outputs += [OutputSpec("target_feat_grad")]
    outputs += [OutputSpec("wgrad", name=nm) for nm, _ in head_names]
    return Artifact(name="vanilla", fn=fn, inputs=specs, outputs=outputs)


def build_all(plan: Plan):
    arts = []
    for p in range(len(plan.partitions)):
        if plan.partitions[p]:
            arts.append(build_worker_fwd(plan, p))
            arts.append(build_worker_bwd(plan, p))
    arts.append(build_leader(plan))
    arts.append(build_vanilla(plan))
    return arts
