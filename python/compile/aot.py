"""AOT lowering: plan.json -> HLO-text artifacts + manifest.json.

Usage (from ``python/``):
    python -m compile.aot --plan ../artifacts/<cfg>/plan.json --out ../artifacts/<cfg>

The interchange format is HLO **text**, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import Plan, build_all


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(art):
    # keep_unused=True: gradients of linear layers do not read the weight
    # value, and jit would otherwise DCE those arguments out of the
    # compiled signature — breaking the manifest's input ordering.
    lowered = jax.jit(art.fn, keep_unused=True).lower(*art.example_args())
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan", required=True)
    ap.add_argument("--out", required=True)
    args = ap.parse_args()

    plan = Plan.load(args.plan)
    os.makedirs(args.out, exist_ok=True)
    arts = build_all(plan)
    manifest = {"config": plan.raw["config"], "arch": plan.arch, "artifacts": {}}
    for art in arts:
        text = lower_artifact(art)
        path = os.path.join(args.out, f"{art.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][art.name] = {
            "inputs": [s.to_json() for s in art.inputs],
            "outputs": [o.to_json() for o in art.outputs],
        }
        print(f"  lowered {art.name}: {len(art.inputs)} inputs, "
              f"{len(art.outputs)} outputs, {len(text)//1024} KiB HLO")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(arts)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
