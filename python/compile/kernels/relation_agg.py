"""Fused masked-mean neighbor aggregation x relation projection (R-GCN's
``AGG_r``): ``out[s] = mean_{k: mask[s,k]=1}(x[s,k,:]) @ w``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the gather is hoisted to
the Rust host layer (it is the *system* cost the paper studies); the
kernel consumes a dense padded ``[S, K, F]`` block. The grid tiles target
nodes (``bs``) and the hidden dimension (``bh``); per grid cell the
neighbor tile is mean-reduced on the VPU and immediately fed to the MXU
matmul, so the reduced ``[bs, F]`` activations never round-trip to HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of ``n`` that is <= target (static shapes only)."""
    best = 1
    for d in range(1, n + 1):
        if n % d == 0 and d <= target:
            best = d
    return best


def _kernel(x_ref, m_ref, w_ref, o_ref):
    x = x_ref[...]  # [bs, K, F]
    m = m_ref[...]  # [bs, K]
    s = (x * m[:, :, None]).sum(axis=1)  # [bs, F]  (VPU reduce)
    cnt = jnp.maximum(m.sum(axis=1), 1.0)
    mean = s / cnt[:, None]
    o_ref[...] = mean @ w_ref[...]  # [bs, bh]   (MXU)


@functools.partial(jax.jit, static_argnames=("block_s", "block_h"))
def relation_agg(x, mask, w, *, block_s: int = 0, block_h: int = 0):
    """``x``: [S, K, F] gathered neighbor features, ``mask``: [S, K]
    validity (0/1 f32), ``w``: [F, H] relation weight. Returns [S, H]."""
    S, K, F = x.shape
    H = w.shape[1]
    bs = block_s or pick_block(S)
    bh = block_h or pick_block(H)
    grid = (S // bs, H // bh)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, K, F), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bs, K), lambda i, j: (i, 0)),
            pl.BlockSpec((F, bh), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bs, bh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((S, H), x.dtype),
        interpret=True,
    )(x, mask, w)


def vmem_bytes(S, K, F, H, block_s=0, block_h=0, dtype_bytes=4):
    """Estimated VMEM footprint of one grid cell (DESIGN/EXPERIMENTS
    §Perf): x-tile + mask + weight column block + output tile."""
    bs = block_s or pick_block(S)
    bh = block_h or pick_block(H)
    return dtype_bytes * (bs * K * F + bs * K + F * bh + bs * bh)


# Differentiable wrapper: Pallas forward, ref-function VJP backward
# (interpret-mode pallas_call does not support reverse-mode autodiff; the
# oracle is numerically identical, so gradients are exact).
import jax as _jax
from . import ref as _ref


@_jax.custom_vjp
def relation_agg_op(x, mask, w):
    return relation_agg(x, mask, w)


def _ra_fwd(x, mask, w):
    return relation_agg(x, mask, w), (x, mask, w)


def _ra_bwd(res, g):
    _, vjp = _jax.vjp(_ref.relation_agg_ref, *res)
    return vjp(g)


relation_agg_op.defvjp(_ra_fwd, _ra_bwd)
