"""Fused masked edge-softmax attention aggregation (R-GAT's ``AGG_r``):

    z      = x @ w                       (neighbor projection, MXU)
    q      = dst_x @ wq                  (query projection, MXU)
    e[s,k] = leaky_relu(ar.z[s,k] + al.q[s])
    alpha  = masked softmax_k(e)
    out[s] = sum_k alpha[s,k] * z[s,k]

Attention logits, the masked softmax and the weighted reduce all stay in
VMEM per node-block; only the ``[bs, H]`` output leaves the kernel —
the TPU re-think of the paper's CUDA edge-softmax (threadblock-per-node)
formulation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .relation_agg import pick_block

NEG = -1e30


def _kernel(x_ref, m_ref, d_ref, w_ref, wq_ref, al_ref, ar_ref, o_ref):
    x = x_ref[...]          # [bs, K, F]
    m = m_ref[...]          # [bs, K]
    dx = d_ref[...]         # [bs, Fd]
    w = w_ref[...]          # [F, H]
    z = jnp.einsum("skf,fh->skh", x, w)      # [bs, K, H]
    q = dx @ wq_ref[...]                     # [bs, H]
    e = (z * ar_ref[...]).sum(-1) + (q * al_ref[...]).sum(-1)[:, None]
    e = jnp.where(e > 0, e, 0.2 * e)         # LeakyReLU(0.2)
    e = jnp.where(m > 0, e, NEG)
    e = e - e.max(axis=1, keepdims=True)
    a = jnp.exp(e) * m
    a = a / jnp.maximum(a.sum(axis=1, keepdims=True), 1e-9)
    o_ref[...] = (a[:, :, None] * z).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_s",))
def gat_agg(x, mask, dst_x, w, wq, al, ar, *, block_s: int = 0):
    """``x``: [S,K,F], ``mask``: [S,K], ``dst_x``: [S,Fd] destination
    features (attention query side), ``w``: [F,H], ``wq``: [Fd,H],
    ``al``/``ar``: [H] attention vectors. Returns [S,H]."""
    S, K, F = x.shape
    Fd = dst_x.shape[1]
    H = w.shape[1]
    bs = block_s or pick_block(S, 64)
    grid = (S // bs,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, K, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs, K), lambda i: (i, 0)),
            pl.BlockSpec((bs, Fd), lambda i: (i, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((Fd, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bs, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, H), x.dtype),
        interpret=True,
    )(x, mask, dst_x, w, wq, al, ar)


# Differentiable wrapper (see relation_agg.py).
from . import ref as _ref


@jax.custom_vjp
def gat_agg_op(x, mask, dst_x, w, wq, al, ar):
    return gat_agg(x, mask, dst_x, w, wq, al, ar)


def _ga_fwd(x, mask, dst_x, w, wq, al, ar):
    return gat_agg(x, mask, dst_x, w, wq, al, ar), (x, mask, dst_x, w, wq, al, ar)


def _ga_bwd(res, g):
    _, vjp = jax.vjp(_ref.gat_agg_ref, *res)
    return vjp(g)


gat_agg_op.defvjp(_ga_fwd, _ga_bwd)
