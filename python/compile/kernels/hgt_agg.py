"""Fused typed multi-head attention aggregation (HGT's ``AGG_r``):

    k      = x @ wk,   v = x @ wv        (per-relation key/value, MXU)
    q      = dst_x @ wq                  (per-relation query)
    e[s,k,h] = <q[s,h,:], k[s,k,h,:]> / sqrt(dh)
    alpha  = masked softmax_k(e)         (per head)
    out[s] = (sum_k alpha * v).reshape(H) @ m_out

Heads are a reshape of the hidden dim (H = heads x dh). The paper's HGT
keys weights by node/edge type; we key by relation — a strict superset
parameterization with identical compute shape (DESIGN.md)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .relation_agg import pick_block

NEG = -1e30


def _make_kernel(heads: int):
    def _kernel(x_ref, m_ref, d_ref, wk_ref, wv_ref, wq_ref, mo_ref, o_ref):
        x = x_ref[...]          # [bs, K, F]
        m = m_ref[...]          # [bs, K]
        dx = d_ref[...]         # [bs, Fd]
        bs, K, _ = x.shape
        H = wk_ref.shape[1]
        dh = H // heads
        k = jnp.einsum("skf,fh->skh", x, wk_ref[...]).reshape(bs, K, heads, dh)
        v = jnp.einsum("skf,fh->skh", x, wv_ref[...]).reshape(bs, K, heads, dh)
        q = (dx @ wq_ref[...]).reshape(bs, heads, dh)
        e = (k * q[:, None]).sum(-1) / jnp.sqrt(jnp.float32(dh))  # [bs,K,heads]
        e = jnp.where(m[:, :, None] > 0, e, NEG)
        e = e - e.max(axis=1, keepdims=True)
        a = jnp.exp(e) * m[:, :, None]
        a = a / jnp.maximum(a.sum(axis=1, keepdims=True), 1e-9)
        out = (a[..., None] * v).sum(axis=1).reshape(bs, H)
        o_ref[...] = out @ mo_ref[...]

    return _kernel


@functools.partial(jax.jit, static_argnames=("heads", "block_s"))
def hgt_agg(x, mask, dst_x, wk, wv, wq, m_out, *, heads: int = 2, block_s: int = 0):
    """``x``: [S,K,F], ``mask``: [S,K], ``dst_x``: [S,Fd], ``wk``/``wv``:
    [F,H], ``wq``: [Fd,H], ``m_out``: [H,H]. Returns [S,H]."""
    S, K, F = x.shape
    Fd = dst_x.shape[1]
    H = wk.shape[1]
    assert H % heads == 0, "hidden must be divisible by heads"
    bs = block_s or pick_block(S, 64)
    grid = (S // bs,)
    return pl.pallas_call(
        _make_kernel(heads),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, K, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs, K), lambda i: (i, 0)),
            pl.BlockSpec((bs, Fd), lambda i: (i, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((Fd, H), lambda i: (0, 0)),
            pl.BlockSpec((H, H), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, H), x.dtype),
        interpret=True,
    )(x, mask, dst_x, wk, wv, wq, m_out)


# Differentiable wrapper (see relation_agg.py). `heads` is static, so the
# custom_vjp closure is built per head count and cached.
from . import ref as _ref

_op_cache = {}


def hgt_agg_op(x, mask, dst_x, wk, wv, wq, m_out, *, heads=2):
    if heads not in _op_cache:

        @jax.custom_vjp
        def op(x, mask, dst_x, wk, wv, wq, m_out):
            return hgt_agg(x, mask, dst_x, wk, wv, wq, m_out, heads=heads)

        def fwd(x, mask, dst_x, wk, wv, wq, m_out):
            return op(x, mask, dst_x, wk, wv, wq, m_out), (
                x, mask, dst_x, wk, wv, wq, m_out,
            )

        def bwd(res, g):
            _, vjp = jax.vjp(
                lambda *a: _ref.hgt_agg_ref(*a, heads=heads), *res
            )
            return vjp(g)

        op.defvjp(fwd, bwd)
        _op_cache[heads] = op
    return _op_cache[heads](x, mask, dst_x, wk, wv, wq, m_out)
