"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal (pytest asserts allclose kernel-vs-ref over hypothesis-generated
shapes/dtypes)."""

import jax.numpy as jnp

NEG = -1e30


def relation_agg_ref(x, mask, w):
    s = (x * mask[:, :, None]).sum(axis=1)
    cnt = jnp.maximum(mask.sum(axis=1), 1.0)
    return (s / cnt[:, None]) @ w


def gat_agg_ref(x, mask, dst_x, w, wq, al, ar):
    z = jnp.einsum("skf,fh->skh", x, w)
    q = dst_x @ wq
    e = (z * ar).sum(-1) + (q * al).sum(-1)[:, None]
    e = jnp.where(e > 0, e, 0.2 * e)
    e = jnp.where(mask > 0, e, NEG)
    e = e - e.max(axis=1, keepdims=True)
    a = jnp.exp(e) * mask
    a = a / jnp.maximum(a.sum(axis=1, keepdims=True), 1e-9)
    return (a[:, :, None] * z).sum(axis=1)


def hgt_agg_ref(x, mask, dst_x, wk, wv, wq, m_out, heads=2):
    S, K, _ = x.shape
    H = wk.shape[1]
    dh = H // heads
    k = jnp.einsum("skf,fh->skh", x, wk).reshape(S, K, heads, dh)
    v = jnp.einsum("skf,fh->skh", x, wv).reshape(S, K, heads, dh)
    q = (dst_x @ wq).reshape(S, heads, dh)
    e = (k * q[:, None]).sum(-1) / jnp.sqrt(jnp.float32(dh))
    e = jnp.where(mask[:, :, None] > 0, e, NEG)
    e = e - e.max(axis=1, keepdims=True)
    a = jnp.exp(e) * mask[:, :, None]
    a = a / jnp.maximum(a.sum(axis=1, keepdims=True), 1e-9)
    out = (a[..., None] * v).sum(axis=1).reshape(S, H)
    return out @ m_out
