"""Layer-1 Pallas kernels for the HGNN relation-aggregation hot spot.

All kernels run with ``interpret=True`` so the lowered HLO executes on the
CPU PJRT client (real-TPU Pallas lowers to Mosaic custom-calls the CPU
plugin cannot run — see DESIGN.md §Hardware-Adaptation).
"""

from .relation_agg import relation_agg
from .gat_agg import gat_agg
from .hgt_agg import hgt_agg
from . import ref

__all__ = ["relation_agg", "gat_agg", "hgt_agg", "ref"]
