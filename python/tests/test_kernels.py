"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles, swept over
shapes and dtypes with hypothesis. This is the core numeric signal for
the compiled artifacts (the same kernels lower into the HLO that Rust
executes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gat_agg, hgt_agg, relation_agg, ref
from compile.kernels.relation_agg import pick_block, vmem_bytes

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


def rand_mask(rng, s, k):
    m = (rng.random((s, k)) > 0.3).astype(np.float32)
    return jnp.asarray(m)


dims = st.tuples(
    st.sampled_from([1, 2, 4, 6, 8, 12]),   # S
    st.integers(1, 5),                      # K
    st.sampled_from([1, 3, 8, 16]),         # F
    st.sampled_from([4, 8, 16]),            # H
)


class TestRelationAgg:
    @settings(max_examples=25, deadline=None)
    @given(dims, st.integers(0, 2**31 - 1))
    def test_matches_ref(self, dims, seed):
        s, k, f, h = dims
        rng = np.random.default_rng(seed)
        x, m, w = rand(rng, s, k, f), rand_mask(rng, s, k), rand(rng, f, h)
        got = relation_agg(x, m, w)
        want = ref.relation_agg_ref(x, m, w)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_all_masked_row_is_zero(self):
        rng = np.random.default_rng(0)
        x, w = rand(rng, 4, 3, 5), rand(rng, 5, 8)
        m = jnp.zeros((4, 3), jnp.float32)
        got = relation_agg(x, m, w)
        np.testing.assert_array_equal(np.asarray(got), np.zeros((4, 8), np.float32))

    def test_mean_semantics_single_neighbor(self):
        rng = np.random.default_rng(1)
        x, w = rand(rng, 2, 4, 3), rand(rng, 3, 4)
        m = jnp.zeros((2, 4), jnp.float32).at[:, 0].set(1.0)
        got = relation_agg(x, m, w)
        want = x[:, 0, :] @ w
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_block_shapes_do_not_change_result(self):
        rng = np.random.default_rng(2)
        x, m, w = rand(rng, 8, 3, 6), rand_mask(rng, 8, 3), rand(rng, 6, 16)
        a = relation_agg(x, m, w, block_s=8, block_h=16)
        b = relation_agg(x, m, w, block_s=2, block_h=4)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_pick_block_divides(self):
        for n in [1, 7, 16, 48, 96, 1024, 25600]:
            b = pick_block(n)
            assert n % b == 0 and b <= 128

    def test_vmem_estimate_positive_and_monotone(self):
        small = vmem_bytes(128, 4, 16, 32, block_s=32)
        big = vmem_bytes(128, 4, 16, 32, block_s=128)
        assert 0 < small < big


class TestGatAgg:
    @settings(max_examples=20, deadline=None)
    @given(dims, st.integers(1, 8), st.integers(0, 2**31 - 1))
    def test_matches_ref(self, dims, fd, seed):
        s, k, f, h = dims
        rng = np.random.default_rng(seed)
        x, m = rand(rng, s, k, f), rand_mask(rng, s, k)
        dx, w, wq = rand(rng, s, fd), rand(rng, f, h), rand(rng, fd, h)
        al, ar = rand(rng, h), rand(rng, h)
        got = gat_agg(x, m, dx, w, wq, al, ar)
        want = ref.gat_agg_ref(x, m, dx, w, wq, al, ar)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_attention_weights_sum_to_one_effectively(self):
        # With identical neighbors, output equals the single projection.
        rng = np.random.default_rng(3)
        xrow = rng.standard_normal((1, 1, 5)).astype(np.float32)
        x = jnp.asarray(np.repeat(np.repeat(xrow, 4, 0), 3, 1))
        m = jnp.ones((4, 3), jnp.float32)
        dx, w, wq = rand(rng, 4, 2), rand(rng, 5, 8), rand(rng, 2, 8)
        al, ar = rand(rng, 8), rand(rng, 8)
        got = gat_agg(x, m, dx, w, wq, al, ar)
        want = x[:, 0, :] @ w
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_all_masked_row_is_zero(self):
        rng = np.random.default_rng(4)
        x = rand(rng, 3, 2, 4)
        m = jnp.zeros((3, 2), jnp.float32)
        dx, w, wq = rand(rng, 3, 3), rand(rng, 4, 8), rand(rng, 3, 8)
        al, ar = rand(rng, 8), rand(rng, 8)
        got = gat_agg(x, m, dx, w, wq, al, ar)
        np.testing.assert_allclose(got, np.zeros((3, 8)), atol=1e-6)


class TestHgtAgg:
    @settings(max_examples=20, deadline=None)
    @given(dims, st.sampled_from([1, 2, 4]), st.integers(0, 2**31 - 1))
    def test_matches_ref(self, dims, heads, seed):
        s, k, f, h = dims
        rng = np.random.default_rng(seed)
        x, m = rand(rng, s, k, f), rand_mask(rng, s, k)
        dx = rand(rng, s, 6)
        wk, wv, wq = rand(rng, f, h), rand(rng, f, h), rand(rng, 6, h)
        mo = rand(rng, h, h)
        got = hgt_agg(x, m, dx, wk, wv, wq, mo, heads=heads)
        want = ref.hgt_agg_ref(x, m, dx, wk, wv, wq, mo, heads=heads)
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)

    def test_rejects_indivisible_heads(self):
        rng = np.random.default_rng(5)
        x, m, dx = rand(rng, 2, 2, 3), rand_mask(rng, 2, 2), rand(rng, 2, 4)
        wk = rand(rng, 3, 6)
        with pytest.raises(AssertionError):
            hgt_agg(x, m, dx, wk, wk, rand(rng, 4, 6), rand(rng, 6, 6), heads=4)

    def test_gradients_flow(self):
        # The `_op` wrappers must be differentiable (worker_bwd recomputes
        # through them) and their VJP must match the oracle's.
        from compile.kernels.hgt_agg import hgt_agg_op

        rng = np.random.default_rng(6)
        x, m, dx = rand(rng, 2, 3, 4), jnp.ones((2, 3)), rand(rng, 2, 4)
        wk, wv, wq, mo = rand(rng, 4, 8), rand(rng, 4, 8), rand(rng, 4, 8), rand(rng, 8, 8)

        def loss(wk):
            return hgt_agg_op(x, m, dx, wk, wv, wq, mo, heads=2).sum()

        def loss_ref(wk):
            return ref.hgt_agg_ref(x, m, dx, wk, wv, wq, mo, heads=2).sum()

        g = jax.grad(loss)(wk)
        g_ref = jax.grad(loss_ref)(wk)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-5, atol=1e-6)
        assert np.abs(np.asarray(g)).sum() > 0


class TestOpWrappers:
    def test_relation_agg_op_grads_match_ref(self):
        from compile.kernels.relation_agg import relation_agg_op

        rng = np.random.default_rng(7)
        x, m, w = rand(rng, 4, 3, 5), rand_mask(rng, 4, 3), rand(rng, 5, 8)

        gk = jax.grad(lambda w: relation_agg_op(x, m, w).sum())(w)
        gr = jax.grad(lambda w: ref.relation_agg_ref(x, m, w).sum())(w)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-5, atol=1e-6)

    def test_gat_agg_op_grads_match_ref(self):
        from compile.kernels.gat_agg import gat_agg_op

        rng = np.random.default_rng(8)
        x, m, dx = rand(rng, 4, 3, 5), rand_mask(rng, 4, 3), rand(rng, 4, 2)
        w, wq = rand(rng, 5, 8), rand(rng, 2, 8)
        al, ar = rand(rng, 8), rand(rng, 8)

        gk = jax.grad(lambda w: gat_agg_op(x, m, dx, w, wq, al, ar).sum())(w)
        gr = jax.grad(lambda w: ref.gat_agg_ref(x, m, dx, w, wq, al, ar).sum())(w)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-5, atol=1e-6)

    def test_block_input_grads_flow(self):
        # Learnable-feature updates need d(block); check it is nonzero.
        from compile.kernels.relation_agg import relation_agg_op

        rng = np.random.default_rng(9)
        x, m, w = rand(rng, 2, 2, 3), jnp.ones((2, 2)), rand(rng, 3, 4)
        gx = jax.grad(lambda x: relation_agg_op(x, m, w).sum())(x)
        assert np.abs(np.asarray(gx)).sum() > 0
