"""Layer-2 model tests: artifact construction from a real plan, shape
checks, RAF≡vanilla equivalence at the jax level (Prop. 1 — the sum of
per-partition worker partials fed through the leader must equal the
vanilla full-tree step), and gradient consistency."""

import json
import os
import subprocess

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import Plan, build_all, build_leader, build_vanilla, build_worker_bwd, build_worker_fwd

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def make_plan(config="mag-tiny"):
    path = os.path.join(REPO, "artifacts", config, "plan.json")
    if not os.path.exists(path):
        heta = os.path.join(REPO, "target", "release", "heta")
        if not os.path.exists(heta):
            pytest.skip("heta binary not built")
        subprocess.run(
            [heta, "plan", "--config", f"configs/{config}.json", "--out", path],
            cwd=REPO,
            check=True,
        )
    return Plan.load(path)


def rand_args(art, seed=0):
    rng = np.random.default_rng(seed)
    args = []
    for s in art.inputs:
        if s.dtype == "i32":
            args.append(jnp.asarray(rng.integers(0, 4, size=tuple(s.shape)), jnp.int32))
        elif s.kind == "mask":
            args.append(jnp.asarray((rng.random(tuple(s.shape)) > 0.25).astype(np.float32)))
        else:
            scale = 0.3 if s.kind == "weight" else 1.0
            args.append(jnp.asarray(rng.standard_normal(tuple(s.shape)).astype(np.float32) * scale))
    return args


@pytest.fixture(scope="module")
def plan():
    return make_plan()


class TestArtifactShapes:
    def test_build_all_artifact_set(self, plan):
        arts = build_all(plan)
        names = [a.name for a in arts]
        assert "leader" in names and "vanilla" in names
        assert any(n.startswith("worker_fwd_p") for n in names)
        assert any(n.startswith("worker_bwd_p") for n in names)

    def test_worker_fwd_output_shapes(self, plan):
        art = build_worker_fwd(plan, 0)
        p1, p2 = art.fn(*rand_args(art))
        assert p1.shape == (plan.batch, plan.hidden)
        assert p2.shape == (plan.batch, plan.hidden)
        assert np.isfinite(np.asarray(p1)).all()

    def test_worker_bwd_matches_manifest(self, plan):
        art = build_worker_bwd(plan, 0)
        outs = art.fn(*rand_args(art))
        assert len(outs) == len(art.outputs)
        for o, spec in zip(outs, art.outputs):
            assert np.isfinite(np.asarray(o)).all(), spec.kind

    def test_leader_shapes(self, plan):
        art = build_leader(plan)
        outs = art.fn(*rand_args(art))
        loss, acc, g1, g2 = outs[0], outs[1], outs[2], outs[3]
        assert loss.shape == ()
        assert acc.shape == ()
        assert g1.shape == (plan.batch, plan.hidden)
        assert g2.shape == (plan.batch, plan.hidden)

    def test_vanilla_runs(self, plan):
        art = build_vanilla(plan)
        outs = art.fn(*rand_args(art))
        assert len(outs) == len(art.outputs)
        assert np.isfinite(float(outs[0]))


class TestEquivalence:
    def test_raf_equals_vanilla(self, plan):
        """Prop. 1: leader(sum of worker partials) == vanilla full step,
        given identical blocks/weights. We drive the vanilla artifact at
        the RAF batch by regenerating the plan's vanilla_batch... instead
        we compare through the shared tree_forward + head path: feed the
        same named inputs to workers+leader and to a single-partition
        'all edges' forward."""
        from compile.model import build_tree_inputs, head_forward, tree_forward

        rng = np.random.default_rng(42)
        b = plan.batch
        all_edges = sorted(e["id"] for e in plan.edges)
        specs_all, ix_all = build_tree_inputs(plan, all_edges, b)

        # One shared pool of named values.
        pool = {}

        def value_for(spec, key):
            if key not in pool:
                if spec.dtype == "i32":
                    pool[key] = jnp.asarray(rng.integers(0, plan.num_classes, size=tuple(spec.shape)), jnp.int32)
                elif spec.kind == "mask":
                    pool[key] = jnp.asarray((rng.random(tuple(spec.shape)) > 0.25).astype(np.float32))
                else:
                    pool[key] = jnp.asarray(rng.standard_normal(tuple(spec.shape)).astype(np.float32) * 0.3)
            return pool[key]

        def key_of(spec):
            if spec.kind in ("block", "mask"):
                return (spec.kind, spec.edge)
            if spec.kind == "weight":
                return ("weight", spec.name)
            return (spec.kind, tuple(spec.shape))

        args_all = [value_for(s, key_of(s)) for s in specs_all]
        p1_full, p2_full = tree_forward(plan, all_edges, b, ix_all, args_all)

        # Per-partition partials with the same pool.
        p1_sum = jnp.zeros_like(p1_full)
        p2_sum = jnp.zeros_like(p2_full)
        for part, edge_ids in enumerate(plan.partitions):
            if not edge_ids:
                continue
            specs_p, ix_p = build_tree_inputs(plan, edge_ids, b)
            args_p = [value_for(s, key_of(s)) for s in specs_p]
            p1, p2 = tree_forward(plan, edge_ids, b, ix_p, args_p)
            p1_sum = p1_sum + p1
            p2_sum = p2_sum + p2

        np.testing.assert_allclose(
            np.asarray(p1_sum), np.asarray(p1_full), rtol=2e-5, atol=2e-5
        )
        np.testing.assert_allclose(
            np.asarray(p2_sum), np.asarray(p2_full), rtol=2e-5, atol=2e-5
        )

        # And the head produces identical loss either way.
        f = plan.target["feat_dim"]
        x_root = jnp.asarray(rng.standard_normal((b, f)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, plan.num_classes, size=(b,)), jnp.int32)
        w1 = jnp.asarray(rng.standard_normal((f, plan.hidden)).astype(np.float32) * 0.3)
        w2 = jnp.asarray(rng.standard_normal((plan.hidden, plan.hidden)).astype(np.float32) * 0.3)
        wh = jnp.asarray(rng.standard_normal((plan.hidden, plan.num_classes)).astype(np.float32) * 0.3)
        loss_a, _ = head_forward(p1_sum, p2_sum, x_root, labels, w1, w2, wh, plan.num_classes)
        loss_b, _ = head_forward(p1_full, p2_full, x_root, labels, w1, w2, wh, plan.num_classes)
        np.testing.assert_allclose(float(loss_a), float(loss_b), rtol=1e-5)


class TestGradients:
    def test_leader_gradients_match_autodiff(self, plan):
        from compile.model import head_forward

        rng = np.random.default_rng(3)
        b, h, c = plan.batch, plan.hidden, plan.num_classes
        f = plan.target["feat_dim"]
        p1 = jnp.asarray(rng.standard_normal((b, h)).astype(np.float32))
        p2 = jnp.asarray(rng.standard_normal((b, h)).astype(np.float32))
        x = jnp.asarray(rng.standard_normal((b, f)).astype(np.float32))
        lab = jnp.asarray(rng.integers(0, c, size=(b,)), jnp.int32)
        w1 = jnp.asarray(rng.standard_normal((f, h)).astype(np.float32) * 0.3)
        w2 = jnp.asarray(rng.standard_normal((h, h)).astype(np.float32) * 0.3)
        wh = jnp.asarray(rng.standard_normal((h, c)).astype(np.float32) * 0.3)

        art = build_leader(plan)
        loss, acc, g1, g2, gx, gw1, gw2, gwh = art.fn(p1, p2, x, lab, w1, w2, wh)
        g1_ref = jax.grad(
            lambda p1: head_forward(p1, p2, x, lab, w1, w2, wh, c)[0]
        )(p1)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g1_ref), rtol=1e-5, atol=1e-6)
        assert 0 <= float(acc) <= b


class TestManifest:
    def test_manifest_serializes(self, plan):
        arts = build_all(plan)
        m = {
            a.name: {
                "inputs": [s.to_json() for s in a.inputs],
                "outputs": [o.to_json() for o in a.outputs],
            }
            for a in arts
        }
        text = json.dumps(m)
        back = json.loads(text)
        assert set(back.keys()) == {a.name for a in arts}
        # Weight specs carry shapes + init.
        for a in arts:
            for s in a.inputs:
                if s.kind == "weight":
                    assert s.init == "glorot"
                    assert all(d > 0 for d in s.shape)
