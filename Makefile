# Build the AOT artifacts every artifact-gated test and bench loads.
#
# Two-step contract per config (see rust/src/config/mod.rs):
#   1. `heta plan` (Rust) computes the metatree, meta-partitioning and
#      padded block shapes  ->  artifacts/<cfg>/plan.json
#   2. python -m compile.aot (JAX) lowers the models to HLO text plus
#      manifest.json         ->  artifacts/<cfg>/*.hlo.txt
#
# Requirements: the Rust toolchain, and python with jax installed
# (`pip install "jax[cpu]"`). Without artifacts, gated tests/benches
# print a skip message pointing here; nothing fails.
#
# `rust/configs` and `rust/artifacts` are symlinks to the repo-root
# directories, because cargo runs tests/benches with cwd = rust/.

# Test-tier configs first (fast to lower), then the bench tier.
CONFIGS := mag-tiny mag-tiny-rgat mag-tiny-hgt mag-tiny-p3 mag-tiny-p4 \
           mag-bench mag-bench-h64 mag-bench-h128 mag-bench-rgat mag-bench-hgt \
           mag240m-bench mag240m-bench-hgt donor-bench donor-bench-rgat \
           freebase-bench igb-bench igb-bench-rgat

MANIFESTS := $(foreach c,$(CONFIGS),artifacts/$(c)/manifest.json)

.PHONY: artifacts artifacts-test clean-artifacts

artifacts: $(MANIFESTS)

# Just the tiny configs the test suite (and the CI net-smoke) gates on.
artifacts-test: $(foreach c,mag-tiny mag-tiny-rgat mag-tiny-hgt mag-tiny-p3,artifacts/$(c)/manifest.json)

artifacts/%/manifest.json: configs/%.json python/compile/aot.py python/compile/model.py
	cargo run --release --bin heta -- plan --config configs/$*.json --out artifacts/$*/plan.json
	cd python && python -m compile.aot --plan ../artifacts/$*/plan.json --out ../artifacts/$*

clean-artifacts:
	rm -rf artifacts
